//! Planned vs unplanned execution: pattern-aware plans (backward-set
//! intersection + automorphism symmetry breaking, `plan::ExecutionPlan`)
//! against DuMato's enumerate-and-filter loops, on the sparse Table III
//! stand-ins where unplanned enumeration materializes orders of magnitude
//! more candidates than any pattern admits.
//!
//! ```
//! cargo bench --bench plans
//! DUMATO_BENCH_SCALE=0.02 cargo bench --bench plans          # CI smoke
//! DUMATO_BENCH_JSON=1 cargo bench --bench plans              # + BENCH_plans.json
//! ```
//!
//! The JSON dump feeds the CI bench-regression gate
//! (`cargo run --bin bench_check`): a planned-row `sim_time` regressing
//! more than 10% against `benches/baselines/BENCH_plans.json` fails CI.

#[path = "support.rs"]
mod support;

use dumato::api::GpmAlgorithm;
use dumato::apps::{CliqueCount, SubgraphQuery};
use dumato::engine::Runner;
use dumato::graph::generators;
use dumato::report::Table;
use dumato::util::fmt_count;

use support::UnplannedClique;

struct Cell {
    timed_out: bool,
    sim: f64,
    gld: u64,
    insts: u64,
    /// comparable result: clique count, or pattern-match count for queries
    count: u64,
}

fn clique_cell<A: GpmAlgorithm>(g: &dumato::graph::CsrGraph, algo: &A) -> Cell {
    let r = Runner::run(g, algo, &support::engine_cfg());
    Cell {
        timed_out: r.timed_out,
        sim: r.metrics.sim_seconds,
        gld: r.metrics.total_gld,
        insts: r.metrics.total_insts,
        count: r.count,
    }
}

fn query_cell(g: &dumato::graph::CsrGraph, q: &SubgraphQuery) -> Cell {
    let r = Runner::run(g, q, &support::engine_cfg());
    Cell {
        timed_out: r.timed_out,
        sim: r.metrics.sim_seconds,
        gld: r.metrics.total_gld,
        insts: r.metrics.total_insts,
        count: q.matches(&r).len() as u64,
    }
}

fn push_rows(t: &mut Table, dataset: &str, app: &str, pattern: &str, pl: Cell, un: Cell) {
    if !pl.timed_out && !un.timed_out {
        assert_eq!(pl.count, un.count, "{dataset}/{app}/{pattern}: planned vs unplanned");
    }
    let speedup = if pl.timed_out || un.timed_out {
        "-".to_string()
    } else {
        format!("{:.2}x", un.sim / pl.sim.max(1e-12))
    };
    for (path, c, sp) in [("planned", &pl, speedup.as_str()), ("unplanned", &un, "1.00x")] {
        t.row(vec![
            dataset.to_string(),
            app.to_string(),
            pattern.to_string(),
            path.to_string(),
            if c.timed_out { "-".into() } else { format!("{:.6}", c.sim) },
            fmt_count(c.gld),
            fmt_count(c.insts),
            if c.timed_out { "-".into() } else { sp.to_string() },
        ]);
    }
}

fn main() {
    support::print_env_banner("plans");
    let s = support::scale();
    let datasets = [
        generators::CITESEER.scaled(s).generate(1),
        generators::DBLP.scaled(s).generate(1),
    ];
    let queries: [(&str, usize, &[(usize, usize)]); 3] = [
        ("4-cycle", 4, &[(0, 1), (1, 2), (2, 3), (3, 0)]),
        ("4-path", 4, &[(0, 1), (1, 2), (2, 3)]),
        ("diamond", 4, &[(0, 1), (1, 2), (0, 2), (0, 3), (2, 3)]),
    ];
    let mut t = Table::new(
        "Planned vs unplanned execution (simulated seconds; speedup on the planned row)",
        &["dataset", "app", "pattern", "path", "sim_time", "gld", "insts", "speedup"],
    );
    // planned 4-cycle counts per dataset, reused by the labeled L=1
    // identity assertion below (no second unlabeled engine run)
    let mut cyc4_counts: Vec<Option<u64>> = Vec::new();
    for g in &datasets {
        println!("dataset={} |V|={} |E|={}", g.name(), g.num_vertices(), g.num_edges());
        for (pname, k, edges) in queries {
            let q = SubgraphQuery::new(k, edges);
            let u = SubgraphQuery::new(k, edges).unplanned();
            let pl = query_cell(g, &q);
            if pname == "4-cycle" {
                cyc4_counts.push((!pl.timed_out).then_some(pl.count));
            }
            push_rows(&mut t, g.name(), "query", pname, pl, query_cell(g, &u));
        }
        let k = 5;
        push_rows(
            &mut t,
            g.name(),
            "clique",
            "5-clique",
            clique_cell(g, &CliqueCount::new(k)),
            clique_cell(g, &UnplannedClique { k }),
        );
    }
    // Labeled rows: the 4-cycle at label cardinality 1/4/16 over the same
    // topologies. Each engine count is asserted against the label-aware
    // CPU oracle (ExecutionPlan::count_from), the L=1 run additionally
    // against the unlabeled planned query; the speedup column is relative
    // to the L=1 run — the label-selectivity win the layer exists for.
    let cyc4: [(usize, usize); 4] = [(0, 1), (1, 2), (2, 3), (3, 0)];
    for (di, g) in datasets.iter().enumerate() {
        let mut base_sim: Option<f64> = None;
        for card in [1usize, 4, 16] {
            let gl = generators::with_random_labels(g.clone(), card, 7);
            let labels: Vec<dumato::graph::Label> =
                (0..4).map(|p| (p % card) as dumato::graph::Label).collect();
            let q = SubgraphQuery::labeled_for(4, &cyc4, &labels, &gl);
            let c = query_cell(&gl, &q);
            if !c.timed_out {
                let oracle: u64 = (0..gl.num_vertices() as u32)
                    .map(|v| q.execution_plan().count_from(&gl, v))
                    .sum();
                assert_eq!(c.count, oracle, "{}/L={card}: engine vs CPU oracle", gl.name());
                if card == 1 {
                    if let Some(plain) = cyc4_counts[di] {
                        assert_eq!(
                            c.count, plain,
                            "{}: cardinality-1 must reproduce the unlabeled count",
                            gl.name()
                        );
                    }
                    base_sim = Some(c.sim);
                }
            }
            let speedup = match (base_sim, c.timed_out) {
                (Some(b), false) => format!("{:.2}x", b / c.sim.max(1e-12)),
                _ => "-".to_string(),
            };
            t.row(vec![
                g.name().to_string(),
                "query-labeled".to_string(),
                format!("4-cycle/L={card}"),
                "planned".to_string(),
                if c.timed_out { "-".into() } else { format!("{:.6}", c.sim) },
                fmt_count(c.gld),
                fmt_count(c.insts),
                speedup,
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "(both paths produce identical counts — asserted above; the planned rows \
         charge only intersected adjacency lists, see DESIGN.md §Plan layer)\n"
    );
    if std::env::var("DUMATO_BENCH_JSON").is_ok() {
        std::fs::write("BENCH_plans.json", t.to_json()).expect("write BENCH_plans.json");
        println!("wrote BENCH_plans.json");
    }
}
