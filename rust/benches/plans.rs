//! Planned vs unplanned execution: pattern-aware plans (backward-set
//! intersection + automorphism symmetry breaking, `plan::ExecutionPlan`)
//! against DuMato's enumerate-and-filter loops, on the sparse Table III
//! stand-ins where unplanned enumeration materializes orders of magnitude
//! more candidates than any pattern admits.
//!
//! ```
//! cargo bench --bench plans
//! DUMATO_BENCH_SCALE=0.02 cargo bench --bench plans          # CI smoke
//! DUMATO_BENCH_JSON=1 cargo bench --bench plans              # + BENCH_plans.json
//! ```
//!
//! The JSON dump feeds the CI bench-regression gate
//! (`cargo run --bin bench_check`): a planned-row `sim_time` regressing
//! more than 10% against `benches/baselines/BENCH_plans.json` fails CI.

#[path = "support.rs"]
mod support;

use dumato::api::GpmAlgorithm;
use dumato::apps::{CliqueCount, MotifCount, SubgraphQuery, SubgraphQuerySet};
use dumato::engine::{Runner, WarpContext};
use dumato::graph::generators;
use dumato::plan::trie::PlanTrie;
use dumato::report::Table;
use dumato::util::fmt_count;

use support::UnplannedClique;

/// One member pattern run through the same trie machinery as the fused
/// path (a 1-pattern trie): the sequential side of the fused-vs-
/// sequential rows, so the comparison isolates prefix sharing.
struct TrieJob {
    trie: PlanTrie,
}

impl GpmAlgorithm for TrieJob {
    fn name(&self) -> &str {
        "trie_job"
    }

    fn k(&self) -> usize {
        self.trie.k()
    }

    fn trie(&self) -> Option<&PlanTrie> {
        Some(&self.trie)
    }

    fn run(&self, ctx: &mut WarpContext) {
        ctx.run_trie(&self.trie);
    }
}

struct Cell {
    timed_out: bool,
    sim: f64,
    gld: u64,
    insts: u64,
    /// comparable result: clique count, or pattern-match count for queries
    count: u64,
}

fn clique_cell<A: GpmAlgorithm>(g: &dumato::graph::CsrGraph, algo: &A) -> Cell {
    let r = Runner::run(g, algo, &support::engine_cfg());
    Cell {
        timed_out: r.timed_out,
        sim: r.metrics.sim_seconds,
        gld: r.metrics.total_gld,
        insts: r.metrics.total_insts,
        count: r.count,
    }
}

fn query_cell(g: &dumato::graph::CsrGraph, q: &SubgraphQuery) -> Cell {
    let r = Runner::run(g, q, &support::engine_cfg());
    Cell {
        timed_out: r.timed_out,
        sim: r.metrics.sim_seconds,
        gld: r.metrics.total_gld,
        insts: r.metrics.total_insts,
        count: q.matches(&r).len() as u64,
    }
}

fn push_rows(t: &mut Table, dataset: &str, app: &str, pattern: &str, pl: Cell, un: Cell) {
    if !pl.timed_out && !un.timed_out {
        assert_eq!(pl.count, un.count, "{dataset}/{app}/{pattern}: planned vs unplanned");
    }
    let speedup = if pl.timed_out || un.timed_out {
        "-".to_string()
    } else {
        format!("{:.2}x", un.sim / pl.sim.max(1e-12))
    };
    for (path, c, sp) in [("planned", &pl, speedup.as_str()), ("unplanned", &un, "1.00x")] {
        t.row(vec![
            dataset.to_string(),
            app.to_string(),
            pattern.to_string(),
            path.to_string(),
            if c.timed_out { "-".into() } else { format!("{:.6}", c.sim) },
            fmt_count(c.gld),
            fmt_count(c.insts),
            if c.timed_out { "-".into() } else { sp.to_string() },
        ]);
    }
}

/// One fused-vs-sequential row pair: the fused job's one-traversal run
/// against the summed per-member 1-pattern trie runs. Asserts (when no
/// side timed out) per-leaf count equality, total equality, and that the
/// fused modeled time never loses to sequential — with a hard 2x floor
/// where `require_2x` is set (the k=4 motif acceptance gate). Returns
/// the fused census for callers that hold an external count reference.
fn fused_group<A: GpmAlgorithm>(
    t: &mut Table,
    g: &dumato::graph::CsrGraph,
    app: &str,
    pattern: &str,
    fused: &A,
    require_2x: bool,
) -> Option<Vec<(u64, u64)>> {
    let members: Vec<dumato::plan::ExecutionPlan> =
        fused.trie().expect("fused_group needs a trie job").plans().to_vec();
    let fr = Runner::run(g, fused, &support::engine_cfg());
    let fc = Cell {
        timed_out: fr.timed_out,
        sim: fr.metrics.sim_seconds,
        gld: fr.metrics.total_gld,
        insts: fr.metrics.total_insts,
        count: fr.count,
    };
    let mut seq = Cell { timed_out: false, sim: 0.0, gld: 0, insts: 0, count: 0 };
    let mut member_counts: Vec<Option<u64>> = Vec::new();
    for pl in &members {
        let job = TrieJob {
            trie: PlanTrie::build(std::slice::from_ref(pl)).expect("1-pattern trie"),
        };
        let r = Runner::run(g, &job, &support::engine_cfg());
        seq.timed_out |= r.timed_out;
        seq.sim += r.metrics.sim_seconds;
        seq.gld += r.metrics.total_gld;
        seq.insts += r.metrics.total_insts;
        seq.count += r.count;
        member_counts.push((!r.timed_out).then_some(r.count));
    }
    if !fc.timed_out {
        for (i, want) in member_counts.iter().enumerate() {
            if let Some(w) = want {
                assert_eq!(
                    fr.leaf_counts[i],
                    *w,
                    "{}/{app}/{pattern}: leaf {i} fused vs sequential",
                    g.name()
                );
            }
        }
    }
    if !fc.timed_out && !seq.timed_out {
        assert_eq!(fc.count, seq.count, "{}/{app}/{pattern}: totals", g.name());
        assert!(
            fc.sim <= seq.sim,
            "{}/{app}/{pattern}: fused must not lose to sequential ({:.6} vs {:.6})",
            g.name(),
            fc.sim,
            seq.sim
        );
        if require_2x {
            assert!(
                fc.sim * 2.0 <= seq.sim,
                "{}/{app}/{pattern}: fused must beat sequential by >= 2x ({:.6} vs {:.6})",
                g.name(),
                fc.sim,
                seq.sim
            );
        }
    }
    let speedup = if fc.timed_out || seq.timed_out {
        "-".to_string()
    } else {
        format!("{:.2}x", seq.sim / fc.sim.max(1e-12))
    };
    for (path, c, sp) in [("fused", &fc, speedup.as_str()), ("sequential", &seq, "1.00x")] {
        t.row(vec![
            g.name().to_string(),
            app.to_string(),
            pattern.to_string(),
            path.to_string(),
            if c.timed_out { "-".into() } else { format!("{:.6}", c.sim) },
            fmt_count(c.gld),
            fmt_count(c.insts),
            if c.timed_out { "-".into() } else { sp.to_string() },
        ]);
    }
    (!fc.timed_out).then_some(fr.patterns)
}

fn main() {
    support::print_env_banner("plans");
    let s = support::scale();
    let datasets = [
        generators::CITESEER.scaled(s).generate(1),
        generators::DBLP.scaled(s).generate(1),
    ];
    let queries: [(&str, usize, &[(usize, usize)]); 3] = [
        ("4-cycle", 4, &[(0, 1), (1, 2), (2, 3), (3, 0)]),
        ("4-path", 4, &[(0, 1), (1, 2), (2, 3)]),
        ("diamond", 4, &[(0, 1), (1, 2), (0, 2), (0, 3), (2, 3)]),
    ];
    let mut t = Table::new(
        "Planned vs unplanned execution (simulated seconds; speedup on the planned row)",
        &["dataset", "app", "pattern", "path", "sim_time", "gld", "insts", "speedup"],
    );
    // planned 4-cycle counts per dataset, reused by the labeled L=1
    // identity assertion below (no second unlabeled engine run)
    let mut cyc4_counts: Vec<Option<u64>> = Vec::new();
    for g in &datasets {
        println!("dataset={} |V|={} |E|={}", g.name(), g.num_vertices(), g.num_edges());
        for (pname, k, edges) in queries {
            let q = SubgraphQuery::new(k, edges);
            let u = SubgraphQuery::new(k, edges).unplanned();
            let pl = query_cell(g, &q);
            if pname == "4-cycle" {
                cyc4_counts.push((!pl.timed_out).then_some(pl.count));
            }
            push_rows(&mut t, g.name(), "query", pname, pl, query_cell(g, &u));
        }
        let k = 5;
        push_rows(
            &mut t,
            g.name(),
            "clique",
            "5-clique",
            clique_cell(g, &CliqueCount::new(k)),
            clique_cell(g, &UnplannedClique { k }),
        );
    }
    // Labeled rows: the 4-cycle at label cardinality 1/4/16 over the same
    // topologies. Each engine count is asserted against the label-aware
    // CPU oracle (ExecutionPlan::count_from), the L=1 run additionally
    // against the unlabeled planned query; the speedup column is relative
    // to the L=1 run — the label-selectivity win the layer exists for.
    let cyc4: [(usize, usize); 4] = [(0, 1), (1, 2), (2, 3), (3, 0)];
    for (di, g) in datasets.iter().enumerate() {
        let mut base_sim: Option<f64> = None;
        for card in [1usize, 4, 16] {
            let gl = generators::with_random_labels(g.clone(), card, 7);
            let labels: Vec<dumato::graph::Label> =
                (0..4).map(|p| (p % card) as dumato::graph::Label).collect();
            let q = SubgraphQuery::labeled_for(4, &cyc4, &labels, &gl);
            let c = query_cell(&gl, &q);
            if !c.timed_out {
                let oracle: u64 = (0..gl.num_vertices() as u32)
                    .map(|v| q.execution_plan().count_from(&gl, v))
                    .sum();
                assert_eq!(c.count, oracle, "{}/L={card}: engine vs CPU oracle", gl.name());
                if card == 1 {
                    if let Some(plain) = cyc4_counts[di] {
                        assert_eq!(
                            c.count, plain,
                            "{}: cardinality-1 must reproduce the unlabeled count",
                            gl.name()
                        );
                    }
                    base_sim = Some(c.sim);
                }
            }
            let speedup = match (base_sim, c.timed_out) {
                (Some(b), false) => format!("{:.2}x", b / c.sim.max(1e-12)),
                _ => "-".to_string(),
            };
            t.row(vec![
                g.name().to_string(),
                "query-labeled".to_string(),
                format!("4-cycle/L={card}"),
                "planned".to_string(),
                if c.timed_out { "-".into() } else { format!("{:.6}", c.sim) },
                fmt_count(c.gld),
                fmt_count(c.insts),
                speedup,
            ]);
        }
    }
    // Fused vs sequential (plan-trie rows, EXPERIMENTS.md §Fused vs
    // sequential): the same pattern set answered by one prefix-sharing
    // trie traversal versus one 1-pattern trie run per member, summed —
    // the sequential side runs the identical walk machinery, so the gap
    // is prefix sharing alone. Leaf counts are asserted equal per
    // member, the fused motif census against the unplanned Algorithm-4
    // reference, fused modeled time <= sequential everywhere, and the
    // k=4 motif group must win by >= 2x (the acceptance floor).
    for g in &datasets {
        for k in [4usize, 5] {
            let census = fused_group(
                &mut t,
                g,
                "motif-fused",
                &format!("motifs/k={k}"),
                &MotifCount::planned(k),
                k == 4,
            );
            if let Some(census) = census {
                let un = Runner::run(g, &MotifCount::new(k), &support::engine_cfg());
                if !un.timed_out {
                    assert_eq!(
                        census,
                        un.patterns,
                        "{}: fused census vs unplanned motif k={k}",
                        g.name()
                    );
                }
            }
        }
        let specs: Vec<String> = queries
            .iter()
            .map(|(_, _, edges)| {
                edges.iter().map(|(a, b)| format!("{a}-{b}")).collect::<Vec<_>>().join(",")
            })
            .collect();
        let parsed = dumato::plan::parse_pattern_set(&specs).expect("bench pattern set");
        let qs = SubgraphQuerySet::for_graph(&parsed, g).expect("bench query-set plans");
        fused_group(&mut t, g, "query-batch", "4cycle+4path+diamond", &qs, false);
    }
    println!("{}", t.render());
    println!(
        "(both paths produce identical counts — asserted above; the planned rows \
         charge only intersected adjacency lists, see DESIGN.md §Plan layer; the \
         fused rows share candidate generation across the pattern set, see \
         DESIGN.md §Plan trie)\n"
    );
    if std::env::var("DUMATO_BENCH_JSON").is_ok() {
        std::fs::write("BENCH_plans.json", t.to_json()).expect("write BENCH_plans.json");
        println!("wrote BENCH_plans.json");
    }
}
