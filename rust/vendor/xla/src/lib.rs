//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate carries the PJRT CPU plugin and is not vendorable
//! offline. This stub reproduces exactly the API surface
//! `runtime::offload` compiles against, with [`PjRtClient::cpu`]
//! reporting the runtime as unavailable — so `--features xla` builds
//! (and its feature-gated code) are compile-checked in CI instead of
//! rotting, while every caller's "skip gracefully when PJRT is absent"
//! path still runs. Swap this `path` dependency for the real crate to
//! enable actual offload.

use std::fmt;

/// Stub error: every fallible entry point returns one.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub build: PJRT is unavailable offline (vendor the real `xla` crate)".into(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[derive(Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_items: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1f32, 2.0]).reshape(&[2]).is_err());
        assert!(Literal.to_vec::<i32>().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "{msg}");
    }
}
