//! Offline shim for the subset of the `anyhow` crate this workspace uses:
//! `Error`, `Result<T>`, `anyhow!`, `bail!`, `ensure!`, and the `Context`
//! extension trait for `Result` and `Option`. The real crate is not
//! vendored in this container; the API below is call-compatible for every
//! use site in the repo (message-carrying errors with a context chain).

use std::error::Error as StdError;
use std::fmt;

/// A message-carrying error with an optional chain of causes.
///
/// Unlike the real `anyhow::Error` this is not a trait-object wrapper; the
/// cause chain is flattened to owned frames at construction. It does
/// implement `std::error::Error`, so the blanket [`Context`] impl covers
/// `Result<T, Error>` as well.
#[derive(Debug)]
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Flatten a standard error and its `source()` chain into frames.
    pub fn from_std<E: StdError + ?Sized>(e: &E) -> Self {
        let source = e.source().map(|s| Box::new(Self::from_std(s)));
        Self {
            msg: e.to_string(),
            source,
        }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        std::iter::successors(Some(self), |e| e.source.as_deref())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the whole context chain, `{}` the outer message
        // (the real crate's behaviour, relied on by `main.rs`).
        if f.alternate() {
            let mut first = true;
            for frame in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", frame.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl StdError for Error {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

macro_rules! impl_from_std {
    ($($ty:ty),+ $(,)?) => {
        $(impl From<$ty> for Error {
            fn from(e: $ty) -> Self {
                Error::from_std(&e)
            }
        })+
    };
}

impl_from_std!(
    std::io::Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::num::TryFromIntError,
    std::str::Utf8Error,
    std::string::FromUtf8Error,
    std::fmt::Error,
);

/// Attach context to the error branch of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // From<ParseIntError>
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert!(f(3).is_err());
        assert!(f(11).is_err());
    }
}
