//! End-to-end tests of the query-service layer (ISSUE 7 acceptance):
//! concurrent mixed queries through the in-process handle must return
//! counts identical to one-shot `Runner` runs, with asserted plan- and
//! result-cache behavior, warm/cold bit-identity, and invalidation.
//!
//! Nothing here may depend on *how* queries batched — the admission
//! window makes batch composition timing-dependent; only counts,
//! cache counters with known lower bounds, and outcome fields that are
//! batching-invariant are asserted.

use std::sync::Arc;
use std::time::Duration;

use dumato::apps::SubgraphQuery;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, CsrGraph, GraphStore};
use dumato::plan::parse_pattern;
use dumato::service::{key_for_spec, Service, ServiceConfig, ServiceHandle};

fn small_engine() -> EngineConfig {
    EngineConfig {
        warps: 64,
        threads: 2,
        ..EngineConfig::default()
    }
}

fn service_over(g: CsrGraph, window_ms: u64) -> Service {
    Service::open(
        GraphStore::new(Arc::new(g)),
        ServiceConfig {
            engine: small_engine(),
            batch_window: Duration::from_millis(window_ms),
            ..ServiceConfig::default()
        },
    )
}

/// One-shot oracle: the count the classic per-query path produces.
fn oneshot_count(g: &CsrGraph, spec: &str) -> u64 {
    let p = parse_pattern(spec).unwrap();
    let q = match &p.labels {
        Some(ls) => SubgraphQuery::labeled_for(p.k, &p.edges, ls, g),
        None => SubgraphQuery::new(p.k, &p.edges),
    };
    let r = Runner::run(g, &q, &small_engine());
    assert!(!r.timed_out && r.fault.is_none());
    q.matches(&r).len() as u64
}

#[test]
fn concurrent_mixed_queries_match_oneshot_counts() {
    // labeled graph: unlabeled patterns see label-blind counts, labeled
    // patterns filter — both flavors go through the same service
    let g = generators::with_random_labels(generators::erdos_renyi(40, 0.3, 3), 2, 9);
    let svc = service_over(g.clone(), 20);
    let h = svc.handle();

    // mixed workload: distinct k=4 patterns, a k=3 repeat, a relabeled
    // isomorph, and labeled wedges (distinct classes exercise admission
    // splitting)
    let specs: Vec<&str> = vec![
        "0-1,1-2,2-3,3-0",     // 4-cycle
        "0-1,1-2,2-3",         // 4-path
        "0-1,1-2,2-0",         // triangle
        "1-2,2-0,0-1",         // triangle, respelled (same key)
        "0-1,0-2,0-3",         // 3-star
        "0-1,1-2,2-0",         // triangle, exact repeat
        "0:0-1:1,1:1-2:0",     // labeled wedge
        "2:0-1:1,1:1-0:0",     // same labeled wedge, vertices renamed
        "0:1-1:0,1:0-2:1",     // genuinely different labeling
    ];
    let expected: Vec<u64> = specs.iter().map(|s| oneshot_count(&g, s)).collect();

    // 4 client threads race the same workload through cloned handles
    let workers: Vec<_> = (0..4)
        .map(|_| {
            let h: ServiceHandle = h.clone();
            let specs: Vec<String> = specs.iter().map(|s| s.to_string()).collect();
            std::thread::spawn(move || {
                specs
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let o = h.query(std::slice::from_ref(s)).unwrap();
                        assert!(o.fault.is_none(), "{s}: {:?}", o.fault);
                        assert!(!o.timed_out);
                        assert_eq!(o.counts.len(), 1);
                        // indices 3/5/7 repeat a key this same thread
                        // already completed — the result is cached by
                        // the time they submit, whatever the batching
                        if matches!(i, 3 | 5 | 7) {
                            assert_eq!(o.result_hits, 1, "spec {i} '{s}' must hit");
                            assert_eq!(o.latency, 0.0);
                        }
                        o.counts[0]
                    })
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    for w in workers {
        assert_eq!(w.join().unwrap(), expected);
    }

    let s = h.stats();
    assert_eq!(s.queries, 4 * specs.len() as u64);
    // 6 distinct keys across the workload (triangle×3 and the wedge
    // respelling collapse); each compiles at most once no matter how
    // the 36 queries raced
    assert_eq!(s.plan_misses, 6, "every distinct key compiles exactly once");
    assert_eq!(s.cold_patterns, 6, "every distinct key runs cold exactly once");
    // stats-level hits count cache *lookups* (batch members sharing a
    // slot share one lookup), so only the guaranteed fast-path hits —
    // the three repeat indices per thread — give a batching-independent
    // lower bound
    assert!(
        s.result_hits >= 12,
        "4 threads x 3 guaranteed repeat hits: {s:?}"
    );
    assert!(s.plan_evictions == 0 && s.result_evictions == 0);
    assert!(s.sim_seconds > 0.0);
    svc.shutdown();
}

#[test]
fn multi_pattern_query_fuses_and_matches_oneshot() {
    let g = generators::erdos_renyi(36, 0.3, 5);
    let svc = service_over(g.clone(), 5);
    let h = svc.handle();
    let set: Vec<String> = ["0-1,1-2,2-3,3-0", "0-1,1-2,2-3", "0-1,0-2,0-3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let o = h.query(&set).unwrap();
    assert!(o.fault.is_none() && !o.timed_out);
    let expected: Vec<u64> = set.iter().map(|s| oneshot_count(&g, s)).collect();
    assert_eq!(o.counts, expected);
    assert_eq!(o.total, expected.iter().sum::<u64>());
    // a subsequent single-pattern query for a member is a result hit
    let again = h.query(&set[..1]).unwrap();
    assert_eq!(again.counts[0], expected[0]);
    assert_eq!(again.result_hits, 1);
    assert_eq!(again.latency, 0.0, "cache hits cost zero modeled time");
    svc.shutdown();
}

#[test]
fn warm_queries_are_bit_identical_and_invalidation_forces_recount() {
    let g = generators::erdos_renyi(32, 0.35, 17);
    let svc = service_over(g, 2);
    let h = svc.handle();
    let spec = vec!["0-1,1-2,2-3,3-0".to_string()];

    let cold = h.query(&spec).unwrap();
    assert_eq!(cold.result_hits, 0);
    let warm = h.query(&spec).unwrap();
    assert_eq!(warm.counts, cold.counts, "hit must be bit-identical to cold");
    assert_eq!(warm.result_hits, 1);

    // explicit invalidation: a stale hit must be impossible
    let key = key_for_spec(&spec[0]).unwrap();
    assert!(h.invalidate_result(&key));
    let recount = h.query(&spec).unwrap();
    assert_eq!(recount.result_hits, 0, "invalidated entry cannot hit");
    assert_eq!(recount.counts, cold.counts, "recount over the same snapshot");
    let s = h.stats();
    assert_eq!(s.result_invalidations, 1);
    assert!(
        s.plan_hits >= 1,
        "recount reuses the cached plan (plans survive result invalidation): {s:?}"
    );
    assert_eq!(s.cold_patterns, 2, "cold run + forced recount");

    // blanket invalidation hook
    assert_eq!(h.invalidate_results(), 1);
    assert_eq!(h.query(&spec).unwrap().result_hits, 0);
    svc.shutdown();
}

#[test]
fn relabeled_isomorph_submissions_share_one_plan_and_result() {
    let g = generators::with_random_labels(generators::erdos_renyi(30, 0.3, 23), 3, 4);
    let svc = service_over(g.clone(), 2);
    let h = svc.handle();
    // the same labeled triangle spelled three ways
    let spellings = [
        "0:1-1:2,1:2-2:0,2:0-0:1",
        "2:1-0:2,0:2-1:0,1:0-2:1",
        "1:1-2:2,2:2-0:0,0:0-1:1",
    ];
    let counts: Vec<u64> = spellings
        .iter()
        .map(|s| h.query(&[s.to_string()]).unwrap().counts[0])
        .collect();
    assert_eq!(counts[0], counts[1]);
    assert_eq!(counts[0], counts[2]);
    assert_eq!(counts[0], oneshot_count(&g, spellings[0]));
    let s = h.stats();
    assert_eq!(s.plan_misses, 1, "one canonical key, one compile");
    assert_eq!(s.cold_patterns, 1);
    assert!(s.result_hits >= 2);
    svc.shutdown();
}

#[test]
fn wire_protocol_end_to_end() {
    use dumato::service::serve_lines;
    let g = generators::erdos_renyi(28, 0.3, 7);
    let tri = oneshot_count(&g, "0-1,1-2,2-0");
    let cyc = oneshot_count(&g, "0-1,1-2,2-3,3-0");
    let svc = service_over(g, 2);
    let h = svc.handle();

    let input = "QUERY 0-1,1-2,2-0\n\
                 BATCH 2\n\
                 QUERY 0-1,1-2,2-3,3-0\n\
                 QUERY 1-2,2-0,0-1\n\
                 STATS\n\
                 INVALIDATE\n\
                 QUIT\n";
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&h, input.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 6, "{out}");
    assert!(lines[0].starts_with(&format!("OK count={tri} counts={tri} ")), "{out}");
    assert!(lines[1].starts_with(&format!("OK count={cyc} ")), "{out}");
    // the batch's respelled triangle is a result-cache hit
    assert!(lines[2].starts_with(&format!("OK count={tri} ")), "{out}");
    assert!(lines[2].contains("hits=1/1"), "{out}");
    assert!(lines[3].starts_with("OK queries=3 "), "{out}");
    assert!(lines[4].starts_with("OK invalidated=2"), "{out}");
    assert_eq!(lines[5], "OK bye", "{out}");
    svc.shutdown();
}

#[test]
fn wire_update_commit_roundtrip_adjusts_cached_count() {
    // the ISSUE-8 acceptance demo, end to end over the wire: a cached
    // count survives an UPDATE+COMMIT as an *adjusted* entry (epoch
    // advanced, old-epoch entry unreachable, new count served warm)
    use dumato::service::serve_lines;
    let g = generators::erdos_renyi(26, 0.3, 41);
    // an absent edge whose endpoints both have neighbors: inserting it
    // strictly grows the wedge count, so a stale hit would be visible
    let (u, v) = (0..26u32)
        .flat_map(|a| ((a + 1)..26).map(move |b| (a, b)))
        .find(|&(a, b)| !g.has_edge(a, b) && g.degree(a) > 0 && g.degree(b) > 0)
        .expect("ER(26, 0.3) is nowhere near complete");
    let pre = oneshot_count(&g, "0-1,1-2");
    let svc = service_over(g, 2);
    let h = svc.handle();

    let input = format!(
        "QUERY 0-1,1-2\n\
         EPOCH\n\
         UPDATE +{u},{v}\n\
         EPOCH\n\
         COMMIT\n\
         QUERY 0-1,1-2\n\
         STATS\n\
         QUIT\n"
    );
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&h, input.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 8, "{out}");
    assert!(lines[0].starts_with(&format!("OK count={pre} ")), "{out}");
    assert_eq!(lines[1], "OK epoch=0 pending=0", "{out}");
    assert_eq!(lines[2], "OK staged=1 pending=1", "{out}");
    assert_eq!(lines[3], "OK epoch=0 pending=1", "staging must not advance the epoch: {out}");
    assert_eq!(lines[4], "OK epoch=1 adjusted=1 invalidated=0", "{out}");
    // the adjusted entry serves the *post*-commit count, warm
    let post = oneshot_count(&h.graph(), "0-1,1-2");
    assert!(post > pre, "inserting {u}-{v} must create wedges");
    assert!(lines[5].starts_with(&format!("OK count={post} ")), "{out}");
    assert!(lines[5].contains("hits=1/1"), "adjusted count must hit warm: {out}");
    assert!(
        lines[6].contains(" epoch=1 commits=1 adjusted=1"),
        "{out}"
    );
    assert_eq!(lines[7], "OK bye", "{out}");
    assert_eq!(h.epoch(), 1);
    svc.shutdown();
}

#[test]
fn faulted_runs_are_reported_and_never_cached() {
    // an undersized extensions slab faults the engine; the service must
    // surface the fault and must NOT serve the partial count later
    let g = generators::complete(64);
    let svc = Service::open(
        GraphStore::new(Arc::new(g)),
        ServiceConfig {
            engine: EngineConfig {
                warps: 64,
                threads: 2,
                ext_slab_cap: Some(8),
                ..EngineConfig::default()
            },
            batch_window: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
    );
    let h = svc.handle();
    let spec = vec!["0-1,1-2,2-3".to_string()];
    let o = h.query(&spec).unwrap();
    let fault = o.fault.expect("slab cap 8 must overflow on K64");
    assert!(fault.contains("slab overflow"), "{fault}");
    let again = h.query(&spec).unwrap();
    assert_eq!(again.result_hits, 0, "faulted counts must not be cached");
    assert!(again.fault.is_some());
    assert_eq!(h.stats().result_hits, 0);
    svc.shutdown();
}
