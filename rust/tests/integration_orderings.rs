//! Ordering / orientation invariance, end to end:
//!
//! - planned counts are invariant under relabeling — for random G(n,p) ×
//!   random connected k <= 5 patterns, the `degree`/`degeneracy`/`random`
//!   relabels reproduce the identity-order count, across devices 1 and 2;
//! - for cliques the oriented path (degeneracy relabel + low->high
//!   orient + `CliqueCount::oriented`) reproduces the same count, also
//!   across devices;
//! - every intersection strategy produces the same counts (charges are
//!   the only thing that may differ);
//! - the oriented TE pool shrinks to the core-bounded out-degree caps;
//! - a mis-sized extensions arena surfaces as `EngineError::SlabOverflow`
//!   through `Runner::try_run` instead of panicking mid-phase.

use dumato::apps::{CliqueCount, SubgraphQuery};
use dumato::canon::bitmap::AdjMat;
use dumato::engine::{EngineConfig, EngineError, IntersectStrategy, Runner, TeArena};
use dumato::graph::ordering::{self, OrderingKind};
use dumato::graph::{generators, VertexId};
use dumato::prop_assert_eq;
use dumato::util::proptest::{check, Config};
use dumato::util::Rng;

fn cfg(devices: usize) -> EngineConfig {
    EngineConfig {
        warps: 8,
        threads: 2,
        devices,
        ..Default::default()
    }
}

/// Random connected pattern on k vertices: random spanning tree + extras.
fn random_pattern(rng: &mut Rng, k: usize) -> Vec<(usize, usize)> {
    let mut m = AdjMat::empty(k);
    for i in 1..k {
        m.set_edge(rng.range(0, i), i);
    }
    for a in 0..k {
        for b in (a + 1)..k {
            if rng.chance(0.35) {
                m.set_edge(a, b);
            }
        }
    }
    let mut edges = Vec::new();
    for a in 0..k {
        for b in (a + 1)..k {
            if m.has_edge(a, b) {
                edges.push((a, b));
            }
        }
    }
    edges
}

#[test]
fn property_planned_counts_are_relabel_invariant() {
    check(
        Config { cases: 10, ..Default::default() },
        "planned counts invariant under degree/degeneracy/random relabels x devices",
        |rng| {
            let n = rng.range(10, 22);
            let p = 0.2 + rng.f64() * 0.25;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6); // 3..=5
            let edges = random_pattern(rng, k);
            let q = SubgraphQuery::new(k, &edges);
            let want = q.matches(&Runner::run(&g, &q, &cfg(1))).len();
            for kind in [OrderingKind::Degree, OrderingKind::Degeneracy, OrderingKind::Random] {
                let h = ordering::apply(&g, kind, rng.next_u64());
                for devices in [1usize, 2] {
                    let got = q.matches(&Runner::run(&h, &q, &cfg(devices))).len();
                    prop_assert_eq!(
                        got,
                        want,
                        "n={n} p={p:.2} k={k} edges={edges:?} {kind:?} devices={devices}"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_oriented_clique_reproduces_identity_counts() {
    check(
        Config { cases: 10, ..Default::default() },
        "oriented clique == identity-order planned clique x orderings x devices",
        |rng| {
            let n = rng.range(12, 26);
            let p = 0.25 + rng.f64() * 0.25;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6);
            let want = Runner::run(&g, &CliqueCount::new(k), &cfg(1)).count;
            for kind in [OrderingKind::None, OrderingKind::Degeneracy, OrderingKind::Random] {
                let o = ordering::orient(&ordering::apply(&g, kind, rng.next_u64()));
                for devices in [1usize, 2] {
                    let r = Runner::run(&o, &CliqueCount::oriented(k), &cfg(devices));
                    prop_assert_eq!(
                        r.count,
                        want,
                        "n={n} p={p:.2} k={k} {kind:?} devices={devices}"
                    );
                    dumato::prop_assert!(r.fault.is_none(), "unexpected fault: {:?}", r.fault);
                }
            }
            Ok(())
        },
    );
}

#[test]
fn intersect_strategies_agree_on_counts_across_orderings() {
    let g = generators::ASTROPH.scaled(0.02).generate(7);
    let q = SubgraphQuery::new(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
    let want_cycles = q.matches(&Runner::run(&g, &q, &cfg(1))).len();
    let want_cliques = Runner::run(&g, &CliqueCount::new(4), &cfg(1)).count;
    for kind in [OrderingKind::None, OrderingKind::Degeneracy] {
        let h = ordering::apply(&g, kind, 1);
        for strategy in [
            IntersectStrategy::Auto,
            IntersectStrategy::Merge,
            IntersectStrategy::Bisect,
            IntersectStrategy::Bitmap,
        ] {
            let mut c = cfg(1);
            c.intersect = strategy;
            assert_eq!(
                q.matches(&Runner::run(&h, &q, &c)).len(),
                want_cycles,
                "{kind:?}/{strategy:?}"
            );
            assert_eq!(
                Runner::run(&h, &CliqueCount::new(4), &c).count,
                want_cliques,
                "{kind:?}/{strategy:?}"
            );
        }
    }
}

#[test]
fn labeled_counts_survive_reordering() {
    // labels must travel with their vertices through a relabel
    let g = generators::with_random_labels(generators::erdos_renyi(24, 0.3, 9), 3, 5);
    let edges = [(0usize, 1usize), (1, 2), (0, 2)];
    let labels = [0u32, 1, 2];
    let q = SubgraphQuery::labeled_for(3, &edges, &labels, &g);
    let want = q.matches(&Runner::run(&g, &q, &cfg(1))).len();
    for kind in [OrderingKind::Degree, OrderingKind::Degeneracy, OrderingKind::Random] {
        let h = ordering::apply(&g, kind, 4);
        let qh = SubgraphQuery::labeled_for(3, &edges, &labels, &h);
        assert_eq!(qh.matches(&Runner::run(&h, &qh, &cfg(1))).len(), want, "{kind:?}");
    }
}

#[test]
fn oriented_pool_shrinks_to_core_bounded_caps() {
    let g = generators::barabasi_albert(400, 4, 11);
    let core = ordering::degeneracy(&g);
    let o = ordering::orient(&ordering::degeneracy_order(&g));
    assert!(o.max_degree() <= core);
    let full = TeArena::pool_bytes(&g, 5, 64);
    let planned = TeArena::plan_pool_bytes(&g, 5, 64);
    let oriented = TeArena::plan_pool_bytes(&o, 5, 64);
    assert!(planned < full, "planned {planned} vs unplanned {full}");
    assert!(oriented < planned, "oriented {oriented} vs planned {planned}");
}

#[test]
fn mis_sized_arena_is_an_err_not_a_panic() {
    let g = generators::complete(64);
    let tiny = EngineConfig { ext_slab_cap: Some(8), ..cfg(1) };
    let err = Runner::try_run(&g, &CliqueCount::new(4), &tiny).unwrap_err();
    assert!(matches!(err, EngineError::SlabOverflow { .. }), "{err:?}");
    assert!(err.to_string().contains("slab overflow"), "{err}");
    // the fleet surfaces the same fault
    let tiny2 = EngineConfig { ext_slab_cap: Some(8), ..cfg(2) };
    let r = Runner::run(&g, &CliqueCount::new(4), &tiny2);
    assert!(matches!(r.fault, Some(EngineError::SlabOverflow { .. })), "{:?}", r.fault);
    // an adequate explicit cap is equivalent to the derived caps
    let roomy = EngineConfig { ext_slab_cap: Some(64), ..cfg(1) };
    let ok = Runner::try_run(&g, &CliqueCount::new(4), &roomy).unwrap();
    assert_eq!(ok.count, Runner::run(&g, &CliqueCount::new(4), &cfg(1)).count);
}

#[test]
fn seeded_orderings_are_deterministic_end_to_end() {
    // the bench matrix joins rows on (dataset, ordering, strategy): the
    // relabeled graphs must be reproducible run to run
    let g = generators::MICO.scaled(0.02).generate(1);
    for kind in [OrderingKind::Degree, OrderingKind::Degeneracy, OrderingKind::Random] {
        let a = ordering::apply(&g, kind, 1);
        let b = ordering::apply(&g, kind, 1);
        assert_eq!(a.offsets(), b.offsets(), "{kind:?}");
        assert_eq!(a.adjacency(), b.adjacency(), "{kind:?}");
    }
    let va: Vec<VertexId> = ordering::degeneracy_peel(&g).0;
    let vb: Vec<VertexId> = ordering::degeneracy_peel(&g).0;
    assert_eq!(va, vb);
}
