//! Fuzz-style hardening of the service wire protocol: ~350 generated
//! malformed request lines (deterministic `util::Rng` streams) driven
//! through `serve_lines` against a *live* service. Contract:
//!
//! - every malformed line gets a one-line `ERR` response with a
//!   category-distinct message — the server never panics, never goes
//!   silent, never answers `OK` to garbage;
//! - the session survives: a valid query after the garbage still
//!   returns the right count.
//!
//! Categories: unknown verbs, empty/whitespace lines, overlong lines,
//! invalid UTF-8, malformed QUERY specs (delegated parser errors),
//! BATCH header abuse, non-QUERY lines inside a BATCH, arguments on
//! no-argument verbs, and the dynamic-graph verbs — malformed UPDATE
//! edge ops (bad sign, missing comma, non-numeric / out-of-range /
//! self-loop endpoints, insert-of-present, delete-of-absent,
//! duplicate-staged, op-count cap) plus COMMIT with nothing staged.

use std::sync::Arc;
use std::time::Duration;

use dumato::engine::EngineConfig;
use dumato::graph::{generators, GraphStore};
use dumato::service::{serve_lines, Service, ServiceConfig};
use dumato::util::Rng;

fn tiny_service() -> Service {
    Service::open(
        GraphStore::new(Arc::new(generators::erdos_renyi(20, 0.3, 13))),
        ServiceConfig {
            engine: EngineConfig {
                warps: 32,
                threads: 2,
                ..EngineConfig::default()
            },
            batch_window: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
    )
}

/// Drive raw bytes through one live session; returns response lines.
fn session(svc: &Service, input: &[u8]) -> Vec<String> {
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&svc.handle(), input, &mut out).unwrap();
    String::from_utf8(out)
        .expect("responses are valid UTF-8")
        .lines()
        .map(|l| l.to_string())
        .collect()
}

/// Random printable junk (no newline) of the given length.
fn junk(rng: &mut Rng, len: usize) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ\
                           0123456789 -:;,.!@#$%^&*()[]{}<>/\\'\"`~+=_|?";
    (0..len)
        .map(|_| CHARS[rng.below(CHARS.len() as u64) as usize] as char)
        .collect()
}

#[test]
fn malformed_lines_get_distinct_errors_and_never_kill_the_session() {
    let svc = tiny_service();
    let mut rng = Rng::new(0xf0220_7);
    // (line, marker the ERR must carry)
    let mut cases: Vec<(String, &str)> = Vec::new();

    for i in 0..60 {
        // unknown verbs: junk words that are not in the vocabulary
        let verb = junk(&mut rng, 3 + i % 8).replace(' ', "_");
        let known = [
            "QUERY", "BATCH", "STATS", "INVALIDATE", "QUIT", "UPDATE", "COMMIT", "EPOCH",
        ]
        .iter()
        .any(|k| verb.eq_ignore_ascii_case(k));
        if !known {
            cases.push((format!("{verb} 0-1,1-2"), "unknown verb"));
        }
    }
    for _ in 0..30 {
        // whitespace-only lines
        let n = 1 + rng.below(6) as usize;
        cases.push((" ".repeat(n), "empty request line"));
    }
    for _ in 0..20 {
        // overlong lines
        let n = 4097 + rng.below(2000) as usize;
        cases.push((format!("QUERY {}", "0".repeat(n)), "exceeds 4096 bytes"));
    }
    for _ in 0..60 {
        // malformed QUERY payloads: the pattern parser's own distinct
        // errors must travel the wire
        let bad = match rng.below(5) {
            0 => ("QUERY 1-1".to_string(), "self-loop"),
            1 => ("QUERY 0-1,2-3".to_string(), "disconnected"),
            2 => ("QUERY 0:0-1,1-2".to_string(), "mixes labeled and unlabeled"),
            3 => ("QUERY 0-1;;0-2".to_string(), "empty pattern spec"),
            // leading 'x' guarantees a non-numeric first vertex token,
            // so random junk can never spell a valid pattern
            _ => (format!("QUERY x{}", junk(&mut rng, 12).replace(';', "")), ""),
        };
        cases.push(bad);
    }
    for _ in 0..40 {
        // BATCH header abuse
        let bad = match rng.below(4) {
            0 => ("BATCH".to_string(), "needs a count"),
            // trailing 'x' keeps all-digit junk from being a valid count
            1 => (
                format!("BATCH {}x", junk(&mut rng, 4).replace(' ', "")),
                "not a number",
            ),
            2 => ("BATCH 0".to_string(), "at least 1"),
            _ => (format!("BATCH {}", 1025 + rng.below(9000)), "exceeds"),
        };
        cases.push(bad);
    }
    for _ in 0..30 {
        // arguments on no-argument verbs
        let verb = ["STATS", "INVALIDATE", "QUIT", "COMMIT", "EPOCH"][rng.below(5) as usize];
        cases.push((format!("{verb} {}", junk(&mut rng, 5)), "no arguments"));
    }

    // -- dynamic-graph verbs -------------------------------------------
    // a twin of tiny_service's graph, so insert-of-present /
    // delete-of-absent cases name real edges instead of guessed ones
    let twin = generators::erdos_renyi(20, 0.3, 13);
    let mut present = Vec::new();
    let mut absent = Vec::new();
    for u in 0..20u32 {
        for v in (u + 1)..20 {
            if twin.has_edge(u, v) {
                present.push((u, v));
            } else {
                absent.push((u, v));
            }
        }
    }
    assert!(present.len() >= 5 && absent.len() >= 9, "seed 13 twin drifted");

    for _ in 0..3 {
        // COMMIT with nothing staged — must come before any case that
        // leaves a successfully staged op behind (the duplicates below)
        cases.push(("COMMIT".to_string(), "nothing staged"));
    }
    for _ in 0..5 {
        // UPDATE with no ops at all
        cases.push(("UPDATE".to_string(), "at least one edge op"));
    }
    for _ in 0..10 {
        // stray ';' making an empty op
        let (u, v) = absent[rng.below(absent.len() as u64) as usize];
        cases.push((format!("UPDATE +{u},{v};;-{u},{v}"), "empty edge op"));
    }
    for _ in 0..15 {
        // bad sign: first char is neither '+' nor '-'
        let c = ['*', '=', '~', '!', '^'][rng.below(5) as usize];
        cases.push((
            format!("UPDATE {c}{},{}", rng.below(20), rng.below(20)),
            "must start with",
        ));
    }
    for _ in 0..10 {
        // no comma between the endpoints
        cases.push((format!("UPDATE +{}", 100 + rng.below(900)), "malformed edge endpoints"));
    }
    for _ in 0..10 {
        // non-numeric endpoint (leading 'x' keeps junk non-numeric)
        cases.push((
            format!("UPDATE +x{},{}", junk(&mut rng, 4).replace([',', ';'], ""), rng.below(20)),
            "is not a vertex id",
        ));
    }
    for _ in 0..10 {
        // self-loops
        let u = rng.below(20);
        cases.push((format!("UPDATE +{u},{u}"), "self-loop"));
    }
    for _ in 0..10 {
        // out-of-range ids (|V| = 20)
        let u = rng.below(20);
        let v = 20 + rng.below(1000);
        cases.push((format!("UPDATE -{u},{v}"), "out of range"));
    }
    for i in 0..5 {
        // insert of an edge the snapshot already has
        let (u, v) = present[i];
        cases.push((format!("UPDATE +{u},{v}"), "insert of already-present edge"));
    }
    for i in 0..5 {
        // delete of an edge the snapshot never had
        let (u, v) = absent[i];
        cases.push((format!("UPDATE -{u},{v}"), "delete of absent edge"));
    }
    for _ in 0..3 {
        // op-count cap (257 ops is still far under the line-length cap)
        let crowded = vec!["+0,1"; 257].join(";");
        cases.push((format!("UPDATE {crowded}"), "exceeding the 256 cap"));
    }
    for i in 0..4 {
        // same edge twice in one line: the first op stages fine, the
        // second fails — an ERR that intentionally leaves the first op
        // pending (ops before the failing one remain staged)
        let (u, v) = absent[5 + i];
        cases.push((format!("UPDATE +{u},{v};+{u},{v}"), "already staged"));
    }

    // feed every case through one session, garbage then a valid probe
    let mut input = String::new();
    for (line, _) in &cases {
        input.push_str(line);
        input.push('\n');
    }
    input.push_str("QUERY 0-1,1-2,2-0\nQUIT\n");
    let lines = session(&svc, input.as_bytes());
    assert_eq!(lines.len(), cases.len() + 2, "one response per request");
    for (i, (case, marker)) in cases.iter().enumerate() {
        assert!(
            lines[i].starts_with("ERR "),
            "case {i} {case:?} answered {:?}",
            lines[i]
        );
        assert!(
            lines[i].len() > 4 && !lines[i].contains('\n'),
            "ERR must carry a one-line message: {:?}",
            lines[i]
        );
        if !marker.is_empty() {
            assert!(
                lines[i].contains(marker),
                "case {i} {case:?}: expected marker {marker:?} in {:?}",
                lines[i]
            );
        }
    }
    let probe = &lines[cases.len()];
    assert!(probe.starts_with("OK count="), "session must survive: {probe}");
    assert_eq!(lines[cases.len() + 1], "OK bye");
    svc.shutdown();
}

#[test]
fn shutdown_verb_drains_and_closes() {
    let svc = tiny_service();
    let lines = session(&svc, b"SHUTDOWN now\nQUERY 0-1,1-2,2-0\nSHUTDOWN\n");
    assert_eq!(lines.len(), 3, "{lines:?}");
    assert!(
        lines[0].starts_with("ERR ") && lines[0].contains("no arguments"),
        "{lines:?}"
    );
    assert!(lines[1].starts_with("OK count="), "{lines:?}");
    assert_eq!(lines[2], "OK shutdown");
    // the service is gone: a later session's query reports shut down
    let lines = session(&svc, b"QUERY 0-1,1-2\nQUIT\n");
    assert!(
        lines[0].starts_with("ERR ") && lines[0].contains("shut down"),
        "{lines:?}"
    );
    svc.shutdown();
}

#[test]
fn fault_spec_junk_errors_instead_of_panicking() {
    use dumato::vgpu::FaultPlan;
    let mut rng = Rng::new(0xfa417);
    for kind in ["slab", "death", "ecc", "xfer"] {
        assert!(FaultPlan::parse(&[format!("{kind}@0")]).is_ok(), "{kind}");
    }
    let mut cases: Vec<(String, &str)> = vec![
        ("slab".into(), "missing '@'"),
        ("warp@3".into(), "unknown fault kind"),
        ("slab@x".into(), "is not a number"),
        ("slab@1:y".into(), "is not a number"),
        ("@1".into(), "unknown fault kind"),
    ];
    for _ in 0..80 {
        let len = 1 + rng.below(16) as usize;
        cases.push((junk(&mut rng, len), ""));
    }
    for (spec, marker) in cases {
        match FaultPlan::parse(&[spec.clone()]) {
            Ok(_) => assert!(marker.is_empty(), "junk {spec:?} parsed as a fault spec"),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    marker.is_empty() || msg.contains(marker),
                    "{spec:?}: expected {marker:?} in {msg}"
                );
            }
        }
    }
}

#[test]
fn invalid_utf8_is_rejected_not_fatal() {
    let svc = tiny_service();
    let mut input: Vec<u8> = Vec::new();
    for i in 0..20u8 {
        input.extend_from_slice(b"QUERY 0-1,1-");
        input.push(0x80 + i); // lone continuation byte
        input.push(b'\n');
    }
    input.extend_from_slice(b"QUERY 0-1,1-2,2-0\nQUIT\n");
    let lines = session(&svc, &input);
    assert_eq!(lines.len(), 22);
    for line in &lines[..20] {
        assert_eq!(line, "ERR request line is not valid UTF-8");
    }
    assert!(lines[20].starts_with("OK count="));
    svc.shutdown();
}

#[test]
fn batch_bodies_reject_non_query_lines_and_truncation() {
    let svc = tiny_service();
    // a 3-slot batch: valid, wrong-verb, malformed — each slot answers
    // in order, then the session continues
    let input = "BATCH 3\n\
                 QUERY 0-1,1-2,2-0\n\
                 STATS\n\
                 QUERY 1-1\n\
                 STATS\n\
                 QUIT\n";
    let lines = session(&svc, input.as_bytes());
    assert_eq!(lines.len(), 5, "{lines:?}");
    assert!(lines[0].starts_with("OK count="), "{lines:?}");
    assert!(lines[1].contains("only QUERY lines are allowed inside a BATCH"), "{lines:?}");
    assert!(lines[2].starts_with("ERR ") && lines[2].contains("self-loop"), "{lines:?}");
    assert!(lines[3].starts_with("OK queries="), "{lines:?}");
    assert_eq!(lines[4], "OK bye");

    // truncation: EOF inside the batch is a distinct error, not a hang
    let lines = session(&svc, b"BATCH 4\nQUERY 0-1,1-2,2-0\n");
    assert_eq!(lines.len(), 2, "{lines:?}");
    assert!(
        lines[0].contains("batch truncated: expected 4 QUERY lines, got 1"),
        "{lines:?}"
    );
    assert!(lines[1].starts_with("OK count="), "submitted members still answer: {lines:?}");
    svc.shutdown();
}
