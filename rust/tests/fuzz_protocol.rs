//! Fuzz-style hardening of the service wire protocol: ~350 generated
//! malformed request lines (deterministic `util::Rng` streams) driven
//! through `serve_lines` against a *live* service. Contract:
//!
//! - every malformed line gets a one-line `ERR` response with a
//!   category-distinct message — the server never panics, never goes
//!   silent, never answers `OK` to garbage;
//! - the session survives: a valid query after the garbage still
//!   returns the right count.
//!
//! Categories: unknown verbs, empty/whitespace lines, overlong lines,
//! invalid UTF-8, malformed QUERY specs (delegated parser errors),
//! BATCH header abuse, non-QUERY lines inside a BATCH, and
//! arguments on no-argument verbs.

use std::sync::Arc;
use std::time::Duration;

use dumato::engine::EngineConfig;
use dumato::graph::generators;
use dumato::service::{serve_lines, Service, ServiceConfig};
use dumato::util::Rng;

fn tiny_service() -> Service {
    Service::start(
        Arc::new(generators::erdos_renyi(20, 0.3, 13)),
        ServiceConfig {
            engine: EngineConfig {
                warps: 32,
                threads: 2,
                ..EngineConfig::default()
            },
            batch_window: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
    )
}

/// Drive raw bytes through one live session; returns response lines.
fn session(svc: &Service, input: &[u8]) -> Vec<String> {
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&svc.handle(), input, &mut out).unwrap();
    String::from_utf8(out)
        .expect("responses are valid UTF-8")
        .lines()
        .map(|l| l.to_string())
        .collect()
}

/// Random printable junk (no newline) of the given length.
fn junk(rng: &mut Rng, len: usize) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ\
                           0123456789 -:;,.!@#$%^&*()[]{}<>/\\'\"`~+=_|?";
    (0..len)
        .map(|_| CHARS[rng.below(CHARS.len() as u64) as usize] as char)
        .collect()
}

#[test]
fn malformed_lines_get_distinct_errors_and_never_kill_the_session() {
    let svc = tiny_service();
    let mut rng = Rng::new(0xf0220_7);
    // (line, marker the ERR must carry)
    let mut cases: Vec<(String, &str)> = Vec::new();

    for i in 0..60 {
        // unknown verbs: junk words that are not in the vocabulary
        let verb = junk(&mut rng, 3 + i % 8).replace(' ', "_");
        let known = ["QUERY", "BATCH", "STATS", "INVALIDATE", "QUIT"]
            .iter()
            .any(|k| verb.eq_ignore_ascii_case(k));
        if !known {
            cases.push((format!("{verb} 0-1,1-2"), "unknown verb"));
        }
    }
    for _ in 0..30 {
        // whitespace-only lines
        let n = 1 + rng.below(6) as usize;
        cases.push((" ".repeat(n), "empty request line"));
    }
    for _ in 0..20 {
        // overlong lines
        let n = 4097 + rng.below(2000) as usize;
        cases.push((format!("QUERY {}", "0".repeat(n)), "exceeds 4096 bytes"));
    }
    for _ in 0..60 {
        // malformed QUERY payloads: the pattern parser's own distinct
        // errors must travel the wire
        let bad = match rng.below(5) {
            0 => ("QUERY 1-1".to_string(), "self-loop"),
            1 => ("QUERY 0-1,2-3".to_string(), "disconnected"),
            2 => ("QUERY 0:0-1,1-2".to_string(), "mixes labeled and unlabeled"),
            3 => ("QUERY 0-1;;0-2".to_string(), "empty pattern spec"),
            // leading 'x' guarantees a non-numeric first vertex token,
            // so random junk can never spell a valid pattern
            _ => (format!("QUERY x{}", junk(&mut rng, 12).replace(';', "")), ""),
        };
        cases.push(bad);
    }
    for _ in 0..40 {
        // BATCH header abuse
        let bad = match rng.below(4) {
            0 => ("BATCH".to_string(), "needs a count"),
            // trailing 'x' keeps all-digit junk from being a valid count
            1 => (
                format!("BATCH {}x", junk(&mut rng, 4).replace(' ', "")),
                "not a number",
            ),
            2 => ("BATCH 0".to_string(), "at least 1"),
            _ => (format!("BATCH {}", 1025 + rng.below(9000)), "exceeds"),
        };
        cases.push(bad);
    }
    for _ in 0..30 {
        // arguments on no-argument verbs
        let verb = ["STATS", "INVALIDATE", "QUIT"][rng.below(3) as usize];
        cases.push((format!("{verb} {}", junk(&mut rng, 5)), "no arguments"));
    }

    // feed every case through one session, garbage then a valid probe
    let mut input = String::new();
    for (line, _) in &cases {
        input.push_str(line);
        input.push('\n');
    }
    input.push_str("QUERY 0-1,1-2,2-0\nQUIT\n");
    let lines = session(&svc, input.as_bytes());
    assert_eq!(lines.len(), cases.len() + 2, "one response per request");
    for (i, (case, marker)) in cases.iter().enumerate() {
        assert!(
            lines[i].starts_with("ERR "),
            "case {i} {case:?} answered {:?}",
            lines[i]
        );
        assert!(
            lines[i].len() > 4 && !lines[i].contains('\n'),
            "ERR must carry a one-line message: {:?}",
            lines[i]
        );
        if !marker.is_empty() {
            assert!(
                lines[i].contains(marker),
                "case {i} {case:?}: expected marker {marker:?} in {:?}",
                lines[i]
            );
        }
    }
    let probe = &lines[cases.len()];
    assert!(probe.starts_with("OK count="), "session must survive: {probe}");
    assert_eq!(lines[cases.len() + 1], "OK bye");
    svc.shutdown();
}

#[test]
fn invalid_utf8_is_rejected_not_fatal() {
    let svc = tiny_service();
    let mut input: Vec<u8> = Vec::new();
    for i in 0..20u8 {
        input.extend_from_slice(b"QUERY 0-1,1-");
        input.push(0x80 + i); // lone continuation byte
        input.push(b'\n');
    }
    input.extend_from_slice(b"QUERY 0-1,1-2,2-0\nQUIT\n");
    let lines = session(&svc, &input);
    assert_eq!(lines.len(), 22);
    for line in &lines[..20] {
        assert_eq!(line, "ERR request line is not valid UTF-8");
    }
    assert!(lines[20].starts_with("OK count="));
    svc.shutdown();
}

#[test]
fn batch_bodies_reject_non_query_lines_and_truncation() {
    let svc = tiny_service();
    // a 3-slot batch: valid, wrong-verb, malformed — each slot answers
    // in order, then the session continues
    let input = "BATCH 3\n\
                 QUERY 0-1,1-2,2-0\n\
                 STATS\n\
                 QUERY 1-1\n\
                 STATS\n\
                 QUIT\n";
    let lines = session(&svc, input.as_bytes());
    assert_eq!(lines.len(), 5, "{lines:?}");
    assert!(lines[0].starts_with("OK count="), "{lines:?}");
    assert!(lines[1].contains("only QUERY lines are allowed inside a BATCH"), "{lines:?}");
    assert!(lines[2].starts_with("ERR ") && lines[2].contains("self-loop"), "{lines:?}");
    assert!(lines[3].starts_with("OK queries="), "{lines:?}");
    assert_eq!(lines[4], "OK bye");

    // truncation: EOF inside the batch is a distinct error, not a hang
    let lines = session(&svc, b"BATCH 4\nQUERY 0-1,1-2,2-0\n");
    assert_eq!(lines.len(), 2, "{lines:?}");
    assert!(
        lines[0].contains("batch truncated: expected 4 QUERY lines, got 1"),
        "{lines:?}"
    );
    assert!(lines[1].starts_with("OK count="), "submitted members still answer: {lines:?}");
    svc.shutdown();
}
