//! Planner correctness and modeled-cost guarantees, end to end:
//!
//! - planned and unplanned enumeration agree on random G(n,p) graphs ×
//!   random connected patterns (k <= 5), as vertex-set lists;
//! - stripping the symmetry restrictions multiplies the count by exactly
//!   the pattern's automorphism-orbit factor (so the first-moved-position
//!   rule is complete: one surviving assignment per vertex set);
//! - plans survive `devices > 1` (fleet sharding + rebalancing);
//! - the planned path's modeled kernel time beats the unplanned path by
//!   the margin the plan layer exists for.

use dumato::api::GpmAlgorithm;
use dumato::apps::{CliqueCount, SubgraphQuery};
use dumato::balance::LbConfig;
use dumato::canon::bitmap::AdjMat;
use dumato::engine::{EngineConfig, Runner, WarpContext};
use dumato::graph::generators;
use dumato::multi::Partition;
use dumato::plan::ExecutionPlan;
use dumato::prop_assert_eq;
use dumato::util::proptest::{check, Config};
use dumato::util::Rng;

/// Bench-shared helpers, including the unplanned clique reference
/// pipeline (one copy for the bench and this test).
#[path = "../benches/support.rs"]
mod support;
use support::UnplannedClique;

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 8,
        threads: 2,
        ..Default::default()
    }
}

/// Minimal planned counter: runs an arbitrary `ExecutionPlan` through the
/// engine primitives and counts full matches with [A1]. Used to exercise
/// plans the shipped apps never build (e.g. restriction-stripped ones).
struct PlanCounter {
    plan: ExecutionPlan,
}

impl GpmAlgorithm for PlanCounter {
    fn name(&self) -> &str {
        "plan_counter"
    }

    fn k(&self) -> usize {
        self.plan.k()
    }

    fn plan(&self) -> Option<&ExecutionPlan> {
        Some(&self.plan)
    }

    fn run(&self, ctx: &mut WarpContext) {
        let k = self.plan.k();
        while ctx.control() {
            if ctx.extend_planned(&self.plan) {
                ctx.filter_plan(&self.plan);
                if ctx.te.len() == k - 1 {
                    ctx.aggregate_counter();
                }
            }
            ctx.move_(false);
        }
    }
}

/// Random connected pattern on k vertices: random spanning tree + extras.
fn random_pattern(rng: &mut Rng, k: usize) -> AdjMat {
    let mut m = AdjMat::empty(k);
    for i in 1..k {
        m.set_edge(rng.range(0, i), i);
    }
    for a in 0..k {
        for b in (a + 1)..k {
            if rng.chance(0.35) {
                m.set_edge(a, b);
            }
        }
    }
    m
}

fn edges_of(m: &AdjMat) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for a in 0..m.k {
        for b in (a + 1)..m.k {
            if m.has_edge(a, b) {
                edges.push((a, b));
            }
        }
    }
    edges
}

#[test]
fn property_planned_equals_unplanned_and_orbit_factor_holds() {
    check(
        Config { cases: 20, ..Default::default() },
        "planned == unplanned; embeddings == matches x |Aut|",
        |rng| {
            let n = rng.range(10, 22);
            let p = 0.2 + rng.f64() * 0.25;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6); // 3..=5
            let pat = random_pattern(rng, k);
            let edges = edges_of(&pat);

            let q = SubgraphQuery::new(k, &edges);
            let u = SubgraphQuery::new(k, &edges).unplanned();
            let mut planned = q.matches(&Runner::run(&g, &q, &cfg()));
            let mut unplanned = u.matches(&Runner::run(&g, &u, &cfg()));
            planned.sort_unstable();
            unplanned.sort_unstable();
            prop_assert_eq!(&planned, &unplanned, "n={n} p={p:.2} k={k} edges={edges:?}");

            // completeness of the symmetry restrictions: without them the
            // engine counts every embedding, |Aut| per vertex set
            let plan = ExecutionPlan::build(&pat);
            let free = PlanCounter { plan: plan.without_restrictions() };
            let embeddings = Runner::run(&g, &free, &cfg()).count;
            prop_assert_eq!(
                embeddings,
                planned.len() as u64 * plan.automorphism_factor(),
                "orbit factor, k={k} edges={edges:?}"
            );
            Ok(())
        },
    );
}

#[test]
fn planned_apps_survive_multiple_devices() {
    let g = generators::ASTROPH.scaled(0.02).generate(7);
    let multi = |devices: usize| EngineConfig {
        warps: 16,
        threads: 2,
        devices,
        partition: Partition::DegreeAware,
        lb: Some(LbConfig::default().with_threshold(0.4)),
        ..Default::default()
    };

    let clique1 = Runner::run(&g, &CliqueCount::new(4), &multi(1));
    let clique3 = Runner::run(&g, &CliqueCount::new(4), &multi(3));
    assert_eq!(clique1.count, clique3.count, "planned clique across devices");

    let q = SubgraphQuery::new(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
    let r1 = Runner::run(&g, &q, &multi(1));
    let r3 = Runner::run(&g, &q, &multi(3));
    let mut m1 = q.matches(&r1);
    let mut m3 = q.matches(&r3);
    m1.sort_unstable();
    m3.sort_unstable();
    assert_eq!(m1, m3, "planned query across devices");
    assert!(r3.metrics.fleet_epochs >= 1);
}

#[test]
fn seed_pruning_matches_the_plan_floor_on_the_fleet() {
    // a star has one vertex of degree >= 2: a triangle plan must root
    // nowhere else, on one device or many
    let g = generators::star(12);
    for devices in [1, 3] {
        let mut c = cfg();
        c.devices = devices;
        let r = Runner::run(&g, &CliqueCount::new(3), &c);
        assert_eq!(r.count, 0, "devices={devices}");
    }
}

#[test]
fn planned_query_is_at_least_5x_faster_modeled() {
    // sparse skewed stand-in: unplanned querying enumerates (and stores)
    // every connected 4-subgraph; the plan generates only 4-cycles
    let g = generators::barabasi_albert(600, 3, 5);
    let q = SubgraphQuery::new(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
    let u = SubgraphQuery::new(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unplanned();
    let rp = Runner::run(&g, &q, &cfg());
    let ru = Runner::run(&g, &u, &cfg());
    let mut mp = q.matches(&rp);
    let mut mu = u.matches(&ru);
    mp.sort_unstable();
    mu.sort_unstable();
    assert_eq!(mp, mu);
    let (planned, unplanned) = (rp.metrics.sim_seconds, ru.metrics.sim_seconds);
    assert!(
        planned * 5.0 <= unplanned,
        "planned {planned:.6}s vs unplanned {unplanned:.6}s: below the 5x bar"
    );
}

#[test]
fn planned_clique_beats_the_unplanned_pipeline_modeled() {
    let g = generators::ASTROPH.scaled(0.05).generate(1);
    let k = 5;
    let rp = Runner::run(&g, &CliqueCount::new(k), &cfg());
    let ru = Runner::run(&g, &UnplannedClique { k }, &cfg());
    assert_eq!(rp.count, ru.count);
    let (planned, unplanned) = (rp.metrics.sim_seconds, ru.metrics.sim_seconds);
    assert!(
        planned * 2.0 <= unplanned,
        "planned {planned:.6}s vs unplanned {unplanned:.6}s: the plan must win clearly"
    );
    assert!(
        rp.metrics.total_gld * 2 <= ru.metrics.total_gld,
        "planned clique must cut transactions: {} vs {}",
        rp.metrics.total_gld,
        ru.metrics.total_gld
    );
}

#[test]
fn parse_pattern_feeds_the_query_app() {
    let parsed = dumato::plan::parse_pattern("0-1,1-2,2-3,3-0").unwrap();
    let (k, edges) = (parsed.k, parsed.edges);
    assert_eq!(k, 4);
    let g = generators::grid(3, 3);
    let q = SubgraphQuery::new(k, &edges);
    let r = Runner::run(&g, &q, &cfg());
    assert_eq!(q.matches(&r).len(), 4); // the four unit squares
    // disconnected edge lists error before any engine work
    assert!(dumato::plan::parse_pattern("0-1,2-3").is_err());
}
