//! Multi-device fleet behaviour end-to-end: every app runs on the fleet
//! with exact results, the scaling model rewards more devices, partition
//! policies differ measurably on skew, and inter-device rebalancing
//! engages (and pays for itself in accounted interconnect time).

use dumato::apps::{CliqueCount, MotifCount, QuasiCliqueCount, SubgraphQuery};
use dumato::balance::LbConfig;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::multi::{Interconnect, Partition};

fn cfg(devices: usize) -> EngineConfig {
    EngineConfig {
        warps: 16,
        threads: 2,
        devices,
        ..Default::default()
    }
}

#[test]
fn all_four_apps_run_on_the_fleet_with_exact_results() {
    let g = generators::erdos_renyi(32, 0.3, 13);

    let clique1 = Runner::run(&g, &CliqueCount::new(4), &cfg(1));
    let clique3 = Runner::run(&g, &CliqueCount::new(4), &cfg(3));
    assert_eq!(clique1.count, clique3.count, "clique");

    let motif1 = Runner::run(&g, &MotifCount::new(3), &cfg(1));
    let motif3 = Runner::run(&g, &MotifCount::new(3), &cfg(3));
    assert_eq!(motif1.patterns, motif3.patterns, "motif");

    let quasi1 = Runner::run(&g, &QuasiCliqueCount::new(4, 0.7), &cfg(1));
    let quasi3 = Runner::run(&g, &QuasiCliqueCount::new(4, 0.7), &cfg(3));
    assert_eq!(quasi1.count, quasi3.count, "quasi-clique");

    let q = SubgraphQuery::new(3, &[(0, 1), (1, 2)]); // wedge
    let r1 = Runner::run(&g, &q, &cfg(1));
    let r3 = Runner::run(&g, &q, &cfg(3));
    let mut m1 = q.matches(&r1);
    let mut m3 = q.matches(&r3);
    m1.sort_unstable();
    m3.sort_unstable();
    assert_eq!(m1, m3, "query matches");
    assert!(!m1.is_empty(), "wedge query should match on an ER graph");
}

#[test]
fn fleet_metrics_expose_per_device_accounting() {
    let g = generators::ASTROPH.scaled(0.03).generate(3);
    let mut c = cfg(4);
    c.warps = 32;
    c.partition = Partition::RoundRobin;
    let r = Runner::run(&g, &CliqueCount::new(4), &c);
    let m = &r.metrics;
    assert_eq!(m.devices, 4);
    assert_eq!(m.device_busy_seconds.len(), 4);
    assert_eq!(m.device_idle_seconds.len(), 4);
    assert!(m.fleet_epochs >= 1);
    assert!(m.warps == 4 * 32);
    // job time covers every device's busy time (it is the max over
    // synced clocks, which only ever add to busy time)
    let max_busy = m.device_busy_seconds.iter().cloned().fold(0.0, f64::max);
    assert!(
        m.sim_seconds >= max_busy,
        "job time {} below busiest device {}",
        m.sim_seconds,
        max_busy
    );
    // with static sharding and no LB, skew shows up as idle time
    assert!(
        m.max_device_idle_seconds() > 0.0,
        "round-robin on a skewed graph should leave some device idle"
    );
}

#[test]
fn fleet_rebalance_engages_on_skew_with_lb() {
    // aggressive intra-device LB chops segments, epoch_segments = 1 turns
    // every stop into a fleet barrier, and the skewed stand-in guarantees
    // some device drains while another still holds queued seeds
    let g = generators::ASTROPH.scaled(0.06).generate(3);
    let reference = Runner::run(&g, &CliqueCount::new(5), &{
        let mut c = cfg(1);
        c.warps = 64;
        c.threads = 4;
        c
    })
    .count;
    let mut c = cfg(4);
    c.warps = 64;
    c.threads = 4;
    c.epoch_segments = 1;
    c.partition = Partition::RoundRobin;
    c.lb = Some(LbConfig {
        threshold: 0.95,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let r = Runner::run(&g, &CliqueCount::new(5), &c);
    assert_eq!(r.count, reference, "rebalancing changed exact counts");
    assert!(r.metrics.fleet_epochs >= 2, "expected multiple fleet epochs");
    assert!(
        r.metrics.fleet_migrations > 0,
        "no inter-device migrations on a skewed workload"
    );
    assert!(r.metrics.fleet_bytes > 0);
    assert!(r.metrics.fleet_xfer_seconds > 0.0);
}

#[test]
fn interconnect_choice_changes_transfer_cost_not_counts() {
    let g = generators::ASTROPH.scaled(0.05).generate(3);
    let mut base = cfg(4);
    base.warps = 64;
    base.epoch_segments = 1;
    base.lb = Some(LbConfig {
        threshold: 0.95,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let mut pcie = base.clone();
    pcie.interconnect = Interconnect::Pcie;
    let mut nvlink = base.clone();
    nvlink.interconnect = Interconnect::NvLink;
    let rp = Runner::run(&g, &CliqueCount::new(4), &pcie);
    let rn = Runner::run(&g, &CliqueCount::new(4), &nvlink);
    assert_eq!(rp.count, rn.count);
    // per-byte+message cost: whenever both runs actually moved traffic,
    // NVLink charges less per unit moved
    if rp.metrics.fleet_migrations > 0 && rn.metrics.fleet_migrations > 0 {
        let per_p = rp.metrics.fleet_xfer_seconds / rp.metrics.fleet_migrations as f64;
        let per_n = rn.metrics.fleet_xfer_seconds / rn.metrics.fleet_migrations as f64;
        assert!(per_n < per_p, "NVLink not cheaper: {per_n} vs {per_p}");
    }
}

#[test]
fn degree_aware_beats_round_robin_on_skewed_partition_quality() {
    // deterministic stand-in + deterministic partitioners = a fixed fact;
    // the scaling bench reports the same effect in simulated seconds
    let g = generators::ASTROPH.scaled(0.06).generate(1);
    for ndev in [2usize, 4, 8] {
        let rr = Partition::RoundRobin.max_device_weight(&g, ndev);
        let da = Partition::DegreeAware.max_device_weight(&g, ndev);
        assert!(
            da <= rr,
            "ndev={ndev}: degree-aware max load {da} worse than round-robin {rr}"
        );
    }
}

#[test]
fn fleet_respects_time_limit() {
    let g = generators::complete(40);
    let mut c = cfg(4);
    c.warps = 4;
    c.time_limit = Some(std::time::Duration::from_millis(5));
    let r = Runner::run(&g, &CliqueCount::new(9), &c);
    assert!(r.timed_out, "fleet run must surface the deadline");
}
