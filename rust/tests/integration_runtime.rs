//! Three-layer integration: AOT HLO artifacts (L1 Pallas kernels inside
//! L2 jax functions) executed through the rust PJRT runtime must agree
//! with the pure-rust engine on real graphs.
//!
//! These tests skip (pass trivially) when `artifacts/` has not been built,
//! and are `#[ignore]`d entirely when the crate is compiled without the
//! `xla` feature (the PJRT runtime is then a stub whose constructor
//! errors): `make test` with the feature enabled builds artifacts first
//! so a full CI run exercises them.

use dumato::apps::CliqueCount;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::runtime::{artifacts_dir, Manifest, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built; skipping runtime integration");
        return None;
    }
    Some(XlaRuntime::new(&dir).expect("PJRT runtime"))
}

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 64,
        threads: 4,
        ..Default::default()
    }
}

#[test]
fn manifest_covers_expected_artifacts() {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    for name in [
        "triangle_256",
        "triangle_512",
        "triangle_1024",
        "motif3_256",
        "intersect_1024x32",
        "intersect_4096x32",
        "intersect_1024x128",
    ] {
        assert!(m.find(name).is_some(), "missing artifact {name}");
        assert!(m.find(name).unwrap().path.exists());
    }
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "XLA-artifact-dependent: needs the xla feature, a PJRT plugin, and `make artifacts` (expected failure in offline builds; see DESIGN.md)"
)]
fn xla_triangles_match_engine_across_graph_families() {
    let Some(mut rt) = runtime() else { return };
    let graphs = vec![
        generators::erdos_renyi(250, 0.04, 11),
        generators::barabasi_albert(500, 4, 13),
        generators::complete(40),
        generators::cycle(300),
        generators::CITESEER.scaled(0.2).generate(3),
    ];
    for g in graphs {
        let xla = rt.triangle_count(&g).unwrap();
        let eng = Runner::run(&g, &CliqueCount::new(3), &cfg()).count;
        assert_eq!(xla, eng, "{}", g.name());
    }
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "XLA-artifact-dependent: needs the xla feature, a PJRT plugin, and `make artifacts` (expected failure in offline builds; see DESIGN.md)"
)]
fn xla_motif3_closed_form_matches_engine() {
    let Some(mut rt) = runtime() else { return };
    let g = generators::barabasi_albert(400, 3, 17);
    let (wedges, triangles) = rt.motif3_census(&g).unwrap();
    let eng = Runner::run(&g, &dumato::apps::MotifCount::new(3), &cfg());
    let mut eng_wedges = 0;
    let mut eng_tris = 0;
    for &(bm, c) in &eng.patterns {
        if bm == 0b11 {
            eng_tris = c;
        } else {
            eng_wedges = c;
        }
    }
    assert_eq!(triangles, eng_tris);
    assert_eq!(wedges, eng_wedges);
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "XLA-artifact-dependent: needs the xla feature, a PJRT plugin, and `make artifacts` (expected failure in offline builds; see DESIGN.md)"
)]
fn intersect_kernel_executes_batches_of_every_variant() {
    let Some(mut rt) = runtime() else { return };
    for (b, w) in [(1024, 32), (4096, 32), (1024, 128), (100, 16), (1, 1)] {
        let cur: Vec<i32> = (0..b * w).map(|i| (i as i32).wrapping_mul(2246822519u32 as i32)).collect();
        let nbr: Vec<i32> = (0..b * w).map(|i| (i as i32).wrapping_mul(-1640531527)).collect();
        let (inter, counts) = rt.intersect_count(b, w, &cur, &nbr).unwrap();
        assert_eq!(inter.len(), b * w);
        assert_eq!(counts.len(), b);
        for i in 0..b * w {
            assert_eq!(inter[i], cur[i] & nbr[i], "({b},{w}) elem {i}");
        }
        for r in 0..b {
            let want: u32 = (0..w)
                .map(|c| (cur[r * w + c] & nbr[r * w + c]).count_ones())
                .sum();
            assert_eq!(counts[r] as u32, want, "({b},{w}) row {r}");
        }
    }
}

#[test]
#[cfg_attr(
    not(feature = "xla"),
    ignore = "XLA-artifact-dependent: needs the xla feature, a PJRT plugin, and `make artifacts` (expected failure in offline builds; see DESIGN.md)"
)]
fn executables_are_cached_across_calls() {
    let Some(mut rt) = runtime() else { return };
    let g = generators::cycle(100);
    let t0 = std::time::Instant::now();
    let a = rt.triangle_count(&g).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let b = rt.triangle_count(&g).unwrap();
    let second = t1.elapsed();
    assert_eq!(a, b);
    // second call skips HLO parse + compile; it must be much faster
    assert!(
        second < first / 2,
        "no caching? first={first:?} second={second:?}"
    );
}
