//! Labeled-matching differential suite — the lockdown for the label
//! layer, end to end:
//!
//! - the labeled planned engine, the label-aware CPU oracle
//!   (`ExecutionPlan::count_from`), and the Peregrine-like baseline agree
//!   on random `G(n,p)` graphs × random connected k <= 5 patterns ×
//!   random labelings of cardinality {1, 2, 4};
//! - cardinality-1 labelings reproduce the pre-label unlabeled counts
//!   exactly for every app (clique, motif, query) — labels of
//!   cardinality 1 are the unlabeled system, bit for bit;
//! - labeled queries survive `devices > 1` (fleet seed sharding must
//!   respect the plan's root label).

use dumato::apps::{CliqueCount, MotifCount, SubgraphQuery};
use dumato::baselines::Peregrine;
use dumato::canon::bitmap::AdjMat;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, Label};
use dumato::multi::Partition;
use dumato::prop_assert_eq;
use dumato::util::proptest::{check, Config};
use dumato::util::Rng;

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 8,
        threads: 2,
        ..Default::default()
    }
}

/// Random connected pattern on k vertices: random spanning tree + extras.
fn random_pattern(rng: &mut Rng, k: usize) -> AdjMat {
    let mut m = AdjMat::empty(k);
    for i in 1..k {
        m.set_edge(rng.range(0, i), i);
    }
    for a in 0..k {
        for b in (a + 1)..k {
            if rng.chance(0.35) {
                m.set_edge(a, b);
            }
        }
    }
    m
}

fn edges_of(m: &AdjMat) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for a in 0..m.k {
        for b in (a + 1)..m.k {
            if m.has_edge(a, b) {
                edges.push((a, b));
            }
        }
    }
    edges
}

#[test]
fn property_labeled_engine_equals_oracle_equals_peregrine() {
    check(
        Config { cases: 18, ..Default::default() },
        "labeled planned engine == count_from oracle == Peregrine",
        |rng| {
            let n = rng.range(10, 20);
            let p = 0.2 + rng.f64() * 0.25;
            let card = *rng.pick(&[1usize, 2, 4]);
            let g = generators::with_random_labels(
                generators::erdos_renyi(n, p, rng.next_u64()),
                card,
                rng.next_u64(),
            );
            let k = rng.range(3, 6); // 3..=5
            let pat = random_pattern(rng, k);
            let edges = edges_of(&pat);
            let labels: Vec<Label> = (0..k).map(|_| rng.below(card as u64) as Label).collect();

            // engine: labeled plan through extend_planned's label filter
            let q = SubgraphQuery::labeled_for(k, &edges, &labels, &g);
            let engine = q.matches(&Runner::run(&g, &q, &cfg())).len() as u64;

            // CPU oracle: the label-aware reference matcher
            let plan = q.execution_plan();
            let oracle: u64 =
                (0..g.num_vertices() as u32).map(|v| plan.count_from(&g, v)).sum();
            prop_assert_eq!(
                engine,
                oracle,
                "engine vs oracle: n={n} p={p:.2} k={k} card={card} labels={labels:?}"
            );

            // independent CPU system: the Peregrine-like threaded sweep
            let mut per = Peregrine::for_plan(plan.clone());
            per.threads = 2;
            let peregrine = per.run(&g).expect("single-plan mode always runs").count;
            prop_assert_eq!(
                engine,
                peregrine,
                "engine vs peregrine: n={n} p={p:.2} k={k} card={card} labels={labels:?}"
            );

            // cardinality 1: the labeled path must reproduce the
            // unlabeled system exactly (same matches, not just counts)
            if card == 1 {
                let u = SubgraphQuery::new(k, &edges);
                let mut mu = u.matches(&Runner::run(&g, &u, &cfg()));
                let mut ml = q.matches(&Runner::run(&g, &q, &cfg()));
                mu.sort_unstable();
                ml.sort_unstable();
                prop_assert_eq!(&ml, &mu, "cardinality-1 vs unlabeled: n={n} k={k}");
            }
            Ok(())
        },
    );
}

#[test]
fn cardinality_one_reproduces_every_unlabeled_count() {
    // clique, motif, and query — the acceptance bar: attaching an
    // all-zero label array must not move any pre-label result
    let g = generators::erdos_renyi(26, 0.3, 11);
    let gl = generators::with_random_labels(g.clone(), 1, 5);
    assert!(gl.is_labeled());

    for k in 3..=5 {
        let want = Runner::run(&g, &CliqueCount::new(k), &cfg()).count;
        let got = Runner::run(&gl, &CliqueCount::new(k), &cfg()).count;
        assert_eq!(got, want, "clique k={k}");
    }

    for k in 3..=4 {
        let want = Runner::run(&g, &MotifCount::new(k), &cfg()).patterns;
        let got = Runner::run(&gl, &MotifCount::new(k), &cfg()).patterns;
        assert_eq!(got, want, "motif k={k}");
    }

    let edges = [(0, 1), (1, 2), (2, 3), (3, 0)];
    let plain = SubgraphQuery::new(4, &edges);
    let labeled = SubgraphQuery::labeled_for(4, &edges, &[0, 0, 0, 0], &gl);
    let mut want = plain.matches(&Runner::run(&g, &plain, &cfg()));
    let mut got = labeled.matches(&Runner::run(&gl, &labeled, &cfg()));
    want.sort_unstable();
    got.sort_unstable();
    assert_eq!(got, want, "query 4-cycle");
}

#[test]
fn labeled_query_agrees_across_devices() {
    // fleet seed sharding must respect the plan's root label: every
    // device count (and the match sets) must equal the single-device run
    let g = generators::with_random_labels(generators::erdos_renyi(80, 0.12, 3), 3, 9);
    let edges = [(0, 1), (1, 2)];
    let labels: [Label; 3] = [0, 1, 2];
    let multi = |devices: usize| EngineConfig {
        warps: 16,
        threads: 2,
        devices,
        partition: Partition::DegreeAware,
        ..Default::default()
    };
    let q = SubgraphQuery::labeled_for(3, &edges, &labels, &g);
    let r1 = Runner::run(&g, &q, &multi(1));
    let mut m1 = q.matches(&r1);
    m1.sort_unstable();
    // the oracle anchors the whole device sweep
    let oracle: u64 =
        (0..g.num_vertices() as u32).map(|v| q.execution_plan().count_from(&g, v)).sum();
    assert_eq!(m1.len() as u64, oracle, "single-device vs oracle");
    for devices in [2, 3] {
        let r = Runner::run(&g, &q, &multi(devices));
        let mut m = q.matches(&r);
        m.sort_unstable();
        assert_eq!(m, m1, "devices={devices}");
    }
}

#[test]
fn labeled_counts_shrink_with_cardinality() {
    // monotonicity sanity: summing a labeled pattern's matches over all
    // label assignments recovers the unlabeled count (wedge, card 2)
    let g = generators::with_random_labels(generators::erdos_renyi(24, 0.25, 6), 2, 4);
    let edges = [(0, 1), (1, 2)];
    let unlabeled = {
        let q = SubgraphQuery::new(3, &edges);
        q.matches(&Runner::run(&g, &q, &cfg())).len() as u64
    };
    let mut labeled_total = 0u64;
    for l0 in 0..2u32 {
        for l1 in 0..2u32 {
            for l2 in l0..2u32 {
                // leaves are symmetric: (l0, l2) unordered to avoid
                // double-counting the wedge's leaf swap
                let q = SubgraphQuery::labeled_for(3, &edges, &[l0, l1, l2], &g);
                let count = q.matches(&Runner::run(&g, &q, &cfg())).len() as u64;
                assert!(count <= unlabeled);
                labeled_total += count;
            }
        }
    }
    assert_eq!(labeled_total, unlabeled, "label classes partition the match set");
}
