//! Cross-system integration tests: the DuMato engine and every baseline
//! must produce identical exact counts on the same graphs — the paper's
//! implicit correctness contract for Table VI comparability.

use dumato::apps::{CliqueCount, MotifCount};
use dumato::balance::LbConfig;
use dumato::baselines::{App, DmDfs, FractalDfs, PangolinBfs, Peregrine};
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 32,
        threads: 4,
        ..Default::default()
    }
}

fn graphs() -> Vec<dumato::graph::CsrGraph> {
    vec![
        generators::erdos_renyi(40, 0.25, 3),
        generators::barabasi_albert(60, 3, 5),
        generators::CITESEER.scaled(0.03).generate(7),
        generators::grid(5, 5),
    ]
}

#[test]
fn all_systems_agree_on_clique_counts() {
    for g in graphs() {
        for k in 3..=5usize {
            let engine = Runner::run(&g, &CliqueCount::new(k), &cfg()).count;
            let mut dfs = DmDfs::new(App::Clique, k);
            dfs.lanes = 128;
            assert_eq!(dfs.run(&g).count, engine, "{} k={k} DM_DFS", g.name());
            let pan = PangolinBfs::new(App::Clique, k).run(&g).unwrap().count;
            assert_eq!(pan, engine, "{} k={k} pangolin", g.name());
            let mut fra = FractalDfs::new(App::Clique, k);
            fra.startup_seconds = 0.0;
            assert_eq!(fra.run(&g).count, engine, "{} k={k} fractal", g.name());
            let per = Peregrine::new(App::Clique, k).run(&g).unwrap().count;
            assert_eq!(per, engine, "{} k={k} peregrine", g.name());
        }
    }
}

#[test]
fn all_systems_agree_on_motif_censuses() {
    for g in graphs() {
        for k in 3..=4usize {
            let mut engine = Runner::run(&g, &MotifCount::new(k), &cfg()).patterns;
            engine.sort_unstable();
            engine.retain(|&(_, c)| c > 0);

            let mut dfs = DmDfs::new(App::Motif, k);
            dfs.lanes = 128;
            assert_eq!(dfs.run(&g).patterns, engine, "{} k={k} DM_DFS", g.name());

            let pan = PangolinBfs::new(App::Motif, k).run(&g).unwrap().patterns;
            assert_eq!(pan, engine, "{} k={k} pangolin", g.name());

            let mut fra = FractalDfs::new(App::Motif, k);
            fra.startup_seconds = 0.0;
            assert_eq!(fra.run(&g).patterns, engine, "{} k={k} fractal", g.name());

            let per = Peregrine::new(App::Motif, k).run(&g).unwrap().patterns;
            assert_eq!(per, engine, "{} k={k} peregrine", g.name());
        }
    }
}

#[test]
fn load_balancing_never_changes_results() {
    for g in graphs() {
        for threshold in [0.1, 0.4, 0.9] {
            let base = Runner::run(&g, &CliqueCount::new(4), &cfg());
            let mut lb_cfg = cfg();
            lb_cfg.lb = Some(LbConfig::default().with_threshold(threshold));
            let lb = Runner::run(&g, &CliqueCount::new(4), &lb_cfg);
            assert_eq!(base.count, lb.count, "{} thr={threshold}", g.name());

            let base_m = Runner::run(&g, &MotifCount::new(4), &cfg());
            let lb_m = Runner::run(&g, &MotifCount::new(4), &lb_cfg);
            assert_eq!(base_m.patterns, lb_m.patterns, "{} motifs", g.name());
        }
    }
}

#[test]
fn warp_and_thread_counts_are_invariant() {
    let g = generators::barabasi_albert(80, 4, 9);
    let reference = Runner::run(&g, &CliqueCount::new(5), &cfg()).count;
    for (warps, threads) in [(1, 1), (7, 3), (256, 8), (1024, 16)] {
        let c = Runner::run(
            &g,
            &CliqueCount::new(5),
            &EngineConfig {
                warps,
                threads,
                ..Default::default()
            },
        )
        .count;
        assert_eq!(c, reference, "warps={warps} threads={threads}");
    }
}

#[test]
fn motif_total_equals_subset_identity() {
    // sum over patterns of a k-census == number of connected induced
    // k-subgraphs, cross-checked against pangolin's independent traversal
    let g = generators::erdos_renyi(20, 0.3, 21);
    let e = Runner::run(&g, &MotifCount::new(4), &cfg());
    let total: u64 = e.patterns.iter().map(|&(_, c)| c).sum();
    let p = PangolinBfs::new(App::Motif, 4).run(&g).unwrap();
    assert_eq!(total, p.count);
}

#[test]
fn deep_k_on_dense_graph() {
    // k = 8 exercises the raw-bitmap pattern path and deep TE stacks
    let g = generators::complete(12);
    let r = Runner::run(&g, &CliqueCount::new(8), &cfg());
    // C(12,8) = 495
    assert_eq!(r.count, 495);
    let m = Runner::run(&g, &MotifCount::new(8), &cfg());
    assert_eq!(m.patterns.len(), 1); // only the 8-clique pattern
    assert_eq!(m.patterns[0].1, 495);
}
