//! Fuzz-style hardening of `plan::parse_pattern` — table-driven over
//! ~250 generated malformed specs (deterministic `util::Rng` streams),
//! five corruption categories, each rejected with its own distinct
//! error message:
//!
//! | category            | example         | message marker                  |
//! |---------------------|-----------------|---------------------------------|
//! | self-loop           | `1-1`, `2:0-2:0`| "self-loop"                     |
//! | missing label       | `0:-1:1`        | "missing label"                 |
//! | non-numeric label   | `0:x-1:1`       | "bad label"                     |
//! | mixed labeled/plain | `0:0-1,1-2`     | "mixes labeled and unlabeled"   |
//! | conflicting labels  | `0:0-1:1,1:2-2:0`| "conflicting labels"           |
//!
//! Plus a valid-spec sweep: randomly generated well-formed labeled and
//! unlabeled specs must parse, with labels recovered exactly.
//!
//! The same treatment covers the engine-config flags `--intersect` and
//! `--ordering`: generated junk values must each be rejected through
//! their own vocabulary error ("unknown intersect strategy ..." vs
//! "unknown ordering ..."), never silently defaulted, while the valid
//! vocabularies round-trip.

use dumato::cli::Args;
use dumato::plan::{parse_pattern, parse_pattern_set};
use dumato::util::Rng;

/// A random connected edge list over `0..k` (path spine + extras),
/// shuffled so corruption sites land anywhere in the spec.
fn random_edges(rng: &mut Rng, k: usize) -> Vec<(usize, usize)> {
    let mut edges: Vec<(usize, usize)> = (0..k - 1).map(|i| (i, i + 1)).collect();
    for a in 0..k {
        for b in (a + 2)..k {
            if rng.chance(0.3) {
                edges.push((a, b));
            }
        }
    }
    rng.shuffle(&mut edges);
    edges
}

/// Render an edge list with labels (`labels[v]` per endpoint) or plain.
fn render(edges: &[(usize, usize)], labels: Option<&[u32]>) -> Vec<String> {
    edges
        .iter()
        .map(|&(a, b)| match labels {
            Some(ls) => format!("{a}:{}-{b}:{}", ls[a], ls[b]),
            None => format!("{a}-{b}"),
        })
        .collect()
}

fn assert_rejected(spec: &str, marker: &str, category: &str) {
    match parse_pattern(spec) {
        Ok(p) => panic!("{category}: spec '{spec}' parsed as {p:?}, expected rejection"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains(marker),
                "{category}: spec '{spec}' rejected with '{msg}', expected marker '{marker}'"
            );
        }
    }
}

#[test]
fn fuzz_malformed_specs_each_reject_with_a_distinct_error() {
    let mut rng = Rng::new(0xFA22);
    let mut total = 0usize;
    for _ in 0..50 {
        let k = rng.range(3, 7);
        let edges = random_edges(&mut rng, k);
        let labels: Vec<u32> = (0..k).map(|_| rng.below(5) as u32).collect();

        // 1. self-loop, in both plain and labeled form
        {
            let mut parts = render(&edges, None);
            let v = rng.range(0, k);
            parts.insert(rng.range(0, parts.len() + 1), format!("{v}-{v}"));
            assert_rejected(&parts.join(","), "self-loop", "plain self-loop");
            let mut lparts = render(&edges, Some(&labels));
            let lv = rng.range(0, k);
            lparts.insert(
                rng.range(0, lparts.len() + 1),
                format!("{lv}:{l}-{lv}:{l}", l = labels[lv]),
            );
            assert_rejected(&lparts.join(","), "self-loop", "labeled self-loop");
            total += 2;
        }

        // 2. missing label after ':' on one random endpoint
        {
            let mut parts = render(&edges, Some(&labels));
            let i = rng.range(0, parts.len());
            let (a, b) = edges[i];
            parts[i] = if rng.chance(0.5) {
                format!("{a}:-{b}:{}", labels[b])
            } else {
                format!("{a}:{}-{b}:", labels[a])
            };
            assert_rejected(&parts.join(","), "missing label", "missing label");
            total += 1;
        }

        // 3. non-numeric label on one random endpoint (no '-' in the
        // junk: the edge splits at the first dash, so a negative label
        // reads as a malformed vertex instead — a different rejection)
        {
            let junk = ["x", "abc", "1a", "l0", "_", "?"][rng.range(0, 6)];
            let mut parts = render(&edges, Some(&labels));
            let i = rng.range(0, parts.len());
            let (a, b) = edges[i];
            parts[i] = format!("{a}:{junk}-{b}:{}", labels[b]);
            assert_rejected(&parts.join(","), "bad label", "non-numeric label");
            total += 1;
        }

        // 4. mixed labeled/unlabeled: strip the label from one endpoint
        {
            let mut parts = render(&edges, Some(&labels));
            let i = rng.range(0, parts.len());
            let (a, b) = edges[i];
            parts[i] = format!("{a}-{b}:{}", labels[b]);
            assert_rejected(
                &parts.join(","),
                "mixes labeled and unlabeled",
                "mixed spec",
            );
            total += 1;
        }

        // 5. conflicting labels: relabel one endpoint occurrence of a
        // vertex that appears in >= 2 edges (the path spine guarantees
        // vertex 1 does)
        {
            let mut parts = render(&edges, Some(&labels));
            let i = parts
                .iter()
                .position(|p| p.starts_with("1:"))
                .or_else(|| parts.iter().position(|p| p.contains("-1:")))
                .expect("vertex 1 appears in the spine");
            let (a, b) = edges[i];
            let bump = |l: u32| l + 1 + rng.below(3) as u32;
            parts[i] = if a == 1 {
                format!("{a}:{}-{b}:{}", bump(labels[1]), labels[b])
            } else {
                format!("{a}:{}-{b}:{}", labels[a], bump(labels[b]))
            };
            assert_rejected(&parts.join(","), "conflicting labels", "conflicting labels");
            total += 1;
        }
    }
    assert!(total >= 250, "fuzz volume regressed: {total} specs");
}

fn assert_set_rejected(specs: &[String], marker: &str, category: &str) {
    match parse_pattern_set(specs) {
        Ok(p) => panic!("{category}: set {specs:?} parsed as {p:?}, expected rejection"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains(marker),
                "{category}: set {specs:?} rejected with '{msg}', expected marker '{marker}'"
            );
        }
    }
}

#[test]
fn fuzz_malformed_pattern_sets_each_reject_with_a_distinct_error() {
    // the set-level corruption categories, same treatment as the per-spec
    // fuzz: empty set, mixed sizes, duplicates up to isomorphism, mixed
    // labeled/unlabeled members, and per-spec errors passing through
    let mut rng = Rng::new(0x5E7F);
    assert_set_rejected(&[], "empty pattern set", "empty set");
    let mut total = 1usize;
    for _ in 0..40 {
        let k = rng.range(3, 6);
        let edges = random_edges(&mut rng, k);
        let base = render(&edges, None).join(",");

        // 1. mixed sizes: one member on k vertices, one on k' != k
        {
            let k2 = if rng.chance(0.5) { k + 1 } else { k + 2 };
            let other = render(&random_edges(&mut rng, k2), None).join(",");
            let set = vec![base.clone(), other];
            assert_set_rejected(&set, "mixes sizes", "mixed sizes");
            total += 1;
        }

        // 2. duplicate up to isomorphism: the same pattern with its edge
        // list shuffled and every edge's endpoints possibly flipped
        {
            let mut perm: Vec<(usize, usize)> = edges
                .iter()
                .map(|&(a, b)| if rng.chance(0.5) { (b, a) } else { (a, b) })
                .collect();
            rng.shuffle(&mut perm);
            let twin = render(&perm, None).join(",");
            let set = vec![base.clone(), twin];
            assert_set_rejected(&set, "duplicate pattern", "isomorphic duplicate");
            total += 1;
        }

        // 3. mixed labeled and unlabeled members
        {
            let labels: Vec<u32> = (0..k).map(|_| rng.below(4) as u32).collect();
            let labeled = render(&edges, Some(&labels)).join(",");
            let set = vec![base.clone(), labeled];
            assert_set_rejected(&set, "mixes labeled and unlabeled", "mixed labeledness");
            total += 1;
        }

        // 4. a malformed member surfaces its own per-spec error
        {
            let v = rng.range(0, k);
            let set = vec![base.clone(), format!("{v}-{v}")];
            assert_set_rejected(&set, "self-loop", "malformed member");
            total += 1;
        }
    }
    assert!(total >= 160, "fuzz volume regressed: {total} sets");

    // and valid sets still pass: distinct patterns, uniform k
    let set = vec!["0-1,1-2,2-3,3-0".to_string(), "0-1,1-2,2-3".to_string()];
    let parsed = parse_pattern_set(&set).unwrap();
    assert_eq!(parsed.len(), 2);
    assert!(parsed.iter().all(|p| p.k == 4));
}

/// Random flag value that is NOT in the valid vocabulary: random ASCII
/// junk, case-flipped valid words, and truncations/extensions.
fn junk_value(rng: &mut Rng, valid: &[&str]) -> String {
    let v = loop {
        let s = match rng.below(4) {
            0 => {
                // random short ASCII word
                let len = rng.range(1, 10);
                (0..len)
                    .map(|_| (b'a' + rng.below(26) as u8) as char)
                    .collect::<String>()
            }
            1 => {
                // case-flipped valid word (parsing is case-sensitive)
                let w = valid[rng.range(0, valid.len())];
                w.to_uppercase()
            }
            2 => {
                // truncated valid word
                let w = valid[rng.range(0, valid.len())];
                w[..rng.range(1, w.len())].to_string()
            }
            _ => {
                // extended valid word
                let w = valid[rng.range(0, valid.len())];
                format!("{w}{}", (b'a' + rng.below(26) as u8) as char)
            }
        };
        if !valid.contains(&s.as_str()) {
            break s;
        }
    };
    v
}

fn flag_args(flag: &str, value: &str) -> Args {
    Args::parse(
        [format!("--{flag}"), value.to_string()].into_iter(),
        &["lb"],
    )
    .unwrap()
}

#[test]
fn fuzz_intersect_and_ordering_flags_reject_junk_with_distinct_errors() {
    const INTERSECT: &[&str] = &["auto", "merge", "bisect", "bitmap"];
    const ORDERING: &[&str] = &["none", "degree", "degeneracy", "random"];
    let mut rng = Rng::new(0x1A7E);
    for _ in 0..100 {
        let junk = junk_value(&mut rng, INTERSECT);
        let err = dumato::config::engine_config(&flag_args("intersect", &junk), 0.4)
            .expect_err(&format!("--intersect {junk} must not default"));
        let msg = format!("{err:#}");
        assert!(
            msg.contains("unknown intersect strategy") && msg.contains(&junk),
            "--intersect {junk}: got '{msg}'"
        );
        assert!(!msg.contains("unknown ordering"), "vocabularies must stay distinct: {msg}");

        let junk = junk_value(&mut rng, ORDERING);
        let mut g = dumato::graph::generators::cycle(6);
        let err = dumato::config::apply_ordering(&mut g, &flag_args("ordering", &junk))
            .expect_err(&format!("--ordering {junk} must not default"));
        let msg = format!("{err:#}");
        assert!(
            msg.contains("unknown ordering") && msg.contains(&junk),
            "--ordering {junk}: got '{msg}'"
        );
        assert!(
            !msg.contains("unknown intersect strategy"),
            "vocabularies must stay distinct: {msg}"
        );
    }
    // the valid vocabularies pass through both paths
    for v in INTERSECT {
        assert!(dumato::config::engine_config(&flag_args("intersect", v), 0.4).is_ok(), "{v}");
    }
    for v in ORDERING {
        let mut g = dumato::graph::generators::cycle(6);
        assert!(dumato::config::apply_ordering(&mut g, &flag_args("ordering", v)).is_ok(), "{v}");
    }
}

#[test]
fn fuzz_valid_specs_parse_and_recover_labels() {
    let mut rng = Rng::new(0x600D);
    for _ in 0..60 {
        let k = rng.range(3, 7);
        let edges = random_edges(&mut rng, k);
        // plain
        let plain = render(&edges, None).join(",");
        let p = parse_pattern(&plain).unwrap_or_else(|e| panic!("'{plain}': {e:#}"));
        assert_eq!(p.k, k, "'{plain}'");
        assert_eq!(p.labels, None);
        let mut want: Vec<(usize, usize)> = edges.clone();
        want.sort_unstable();
        want.dedup();
        assert_eq!(p.edges, want, "'{plain}'");
        // labeled
        let labels: Vec<u32> = (0..k).map(|_| rng.below(4) as u32).collect();
        let spec = render(&edges, Some(&labels)).join(",");
        let lp = parse_pattern(&spec).unwrap_or_else(|e| panic!("'{spec}': {e:#}"));
        assert_eq!(lp.k, k, "'{spec}'");
        assert_eq!(lp.edges, want, "'{spec}'");
        assert_eq!(lp.labels, Some(labels), "'{spec}'");
    }
}
