//! Chaos differential suite for the fault-tolerance layer.
//!
//! The invariant under test: with a seeded, deterministic [`FaultPlan`]
//! armed, a run is **exact or structured-faulted — never silently
//! wrong**. A fleet with survivors quarantines the victim and re-deals
//! its work (counts match the fault-free reference bit-for-bit, `fault
//! == None`); a run with no survivors aborts with a structured
//! [`EngineError`] (`fault == Some`, partial counts clearly flagged).
//! The same plan on the same input reproduces the same failure, so
//! every assertion here is a fixed fact, not a flake.

use dumato::apps::{CliqueCount, MotifCount, SubgraphQuery};
use dumato::engine::{EngineConfig, EngineError, Runner};
use dumato::graph::generators;
use dumato::vgpu::FaultPlan;

fn cfg(devices: usize, specs: &[String]) -> EngineConfig {
    EngineConfig {
        warps: 16,
        threads: 2,
        devices,
        faults: FaultPlan::parse(specs).expect("test specs are well-formed"),
        ..Default::default()
    }
}

/// A deterministic family of fault schedules: single faults of every
/// kind across victims and anchors, plus a compound plan mixing
/// death + ecc + a transfer failure.
fn chaos_plans() -> Vec<Vec<String>> {
    let mut plans = Vec::new();
    for s in 0..2u64 {
        plans.push(vec![format!("death@{}:{}", s % 2, s)]);
        plans.push(vec![format!("slab@{}:{}", 1 + s % 2, s)]);
        plans.push(vec![format!("ecc@{}:{}", s % 3, s)]);
        plans.push(vec![
            format!("death@0:{s}"),
            format!("ecc@{}:{}", s % 2, s + 1),
            format!("xfer@{s}"),
        ]);
    }
    plans
}

/// `fault == None` must mean exact; `fault == Some` must be recorded in
/// the per-device fault list. Returns (recovered, fatal) as 0/1.
fn check_exact_or_faulted<T: PartialEq + std::fmt::Debug>(
    r: &dumato::engine::RunReport,
    got: &T,
    want: &T,
    what: &str,
) -> (u32, u32) {
    match &r.fault {
        None => {
            assert_eq!(got, want, "{what}: clean-reported run with wrong counts");
            (u32::from(!r.faults.is_empty()), 0)
        }
        Some(_) => {
            assert!(
                !r.faults.is_empty(),
                "{what}: fatal fault missing from the per-device list"
            );
            (0, 1)
        }
    }
}

#[test]
fn chaos_runs_are_exact_or_structured_never_silently_wrong() {
    let g = generators::erdos_renyi(36, 0.25, 7);
    let clique = CliqueCount::new(4);
    let query = SubgraphQuery::new(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]); // 4-cycle
    let motif = MotifCount::planned(4);
    let clique_ref = Runner::run(&g, &clique, &cfg(1, &[])).count;
    let query_ref = Runner::run(&g, &query, &cfg(1, &[])).count;
    let motif_ref = Runner::run(&g, &motif, &cfg(1, &[])).patterns;
    assert!(clique_ref > 0 && query_ref > 0, "references must be non-trivial");

    let (mut recovered, mut fatal) = (0u32, 0u32);
    for devices in [1usize, 2, 4] {
        for plan in chaos_plans() {
            let label = format!("devices={devices} plan={plan:?}");

            // cfg() parses a fresh plan per job: clones share the
            // fire-once latches, so reusing one plan would leave the
            // later jobs running against already-consumed faults
            let r = Runner::run(&g, &clique, &cfg(devices, &plan));
            let (rec, fat) =
                check_exact_or_faulted(&r, &r.count, &clique_ref, &format!("clique {label}"));
            recovered += rec;
            fatal += fat;

            let r = Runner::run(&g, &query, &cfg(devices, &plan));
            let (rec, fat) =
                check_exact_or_faulted(&r, &r.count, &query_ref, &format!("query {label}"));
            recovered += rec;
            fatal += fat;

            let r = Runner::run(&g, &motif, &cfg(devices, &plan));
            let (rec, fat) =
                check_exact_or_faulted(&r, &r.patterns, &motif_ref, &format!("motif {label}"));
            recovered += rec;
            fatal += fat;
        }
    }
    // the matrix must actually exercise both arms, or the invariant is
    // vacuous (plans anchored past the run's horizon never fire)
    assert!(recovered > 0, "no chaos run recovered from a fault");
    assert!(fatal > 0, "no chaos run hit a fatal fault");
}

#[test]
fn single_device_failure_on_a_fleet_recovers_exactly() {
    let g = generators::erdos_renyi(36, 0.25, 7);
    let clique = CliqueCount::new(4);
    for devices in [2usize, 4] {
        let reference = Runner::run(&g, &clique, &cfg(devices, &[])).count;
        for victim in 0..devices {
            let r = Runner::run(
                &g,
                &clique,
                &cfg(devices, &[format!("death@0:{victim}")]),
            );
            assert!(
                r.fault.is_none(),
                "devices={devices} victim={victim}: recovered run reports fatal {:?}",
                r.fault
            );
            assert_eq!(r.count, reference, "devices={devices} victim={victim}");
            assert_eq!(r.faults.len(), 1);
            assert!(
                matches!(r.faults[0], (d, EngineError::DeviceDead { .. }) if d == victim),
                "wrong fault recorded: {:?}",
                r.faults
            );
            assert_eq!(r.metrics.device_faults, 1);
        }
    }
}

#[test]
fn trie_job_recovers_device_loss_via_root_rerun() {
    let g = generators::erdos_renyi(36, 0.25, 7);
    let motif = MotifCount::planned(4);
    let reference = Runner::run(&g, &motif, &cfg(1, &[])).patterns;
    let r = Runner::run(&g, &motif, &cfg(3, &["death@0:1".to_string()]));
    assert!(r.fault.is_none(), "fatal on a 3-device fleet: {:?}", r.fault);
    assert_eq!(r.patterns, reference, "per-pattern counts drifted after recovery");
    assert_eq!(r.metrics.device_faults, 1);
}

#[test]
fn all_devices_dead_aborts_with_structured_fault() {
    let g = generators::erdos_renyi(36, 0.25, 7);
    let r = Runner::run(
        &g,
        &CliqueCount::new(4),
        &cfg(2, &["death@0:0".to_string(), "death@0:1".to_string()]),
    );
    assert!(
        matches!(r.fault, Some(EngineError::DeviceDead { .. })),
        "expected a fatal DeviceDead, got {:?}",
        r.fault
    );
    assert_eq!(r.faults.len(), 2, "both device deaths must be recorded");
}

#[test]
fn fault_spec_rejections_surface_distinct_cli_errors() {
    let err = |s: &str| {
        format!(
            "{:#}",
            FaultPlan::parse(&[s.to_string()]).expect_err("must reject")
        )
    };
    assert!(err("slab").contains("missing '@'"));
    assert!(err("warp@3").contains("unknown fault kind"));
    assert!(err("slab@x").contains("not a number"));
    assert!(err("death@1:z").contains("fault seed 'z' is not a number"));
    let ok = FaultPlan::parse(&["death@0:1".into(), "xfer@2".into()]).unwrap();
    assert!(ok.is_armed());
}
