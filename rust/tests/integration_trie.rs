//! Fused multi-pattern execution, end to end:
//!
//! - the fused plan-trie traversal, the sequential per-pattern planned
//!   engine, and the unplanned motif classification all agree on random
//!   G(n,p) graphs, across k in {3,4,5}, devices in {1,2}, and every
//!   intersection strategy;
//! - every leaf counter matches the member plan's CPU oracle
//!   (`ExecutionPlan::count_from` summed over seeds);
//! - prefix sharing is real: the trie holds strictly fewer interior
//!   nodes than the member plans laid side by side (k >= 4);
//! - labeled pattern sets ride the same machinery;
//! - intra-device load balancing stays exact on trie jobs (the
//!   `seed_only` donation restriction).

use dumato::api::GpmAlgorithm;
use dumato::apps::{MotifCount, SubgraphQuerySet};
use dumato::balance::LbConfig;
use dumato::engine::{EngineConfig, IntersectStrategy, Runner, WarpContext};
use dumato::graph::generators;
use dumato::plan::trie::PlanTrie;
use dumato::plan::{parse_pattern_set, ExecutionPlan};
use dumato::util::proptest::{check, Config};

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 8,
        threads: 2,
        ..Default::default()
    }
}

/// Minimal sequential planned counter (the pre-trie execution model): one
/// full engine run per pattern through `extend_planned`/`filter_plan`.
struct PlanCounter {
    plan: ExecutionPlan,
}

impl GpmAlgorithm for PlanCounter {
    fn name(&self) -> &str {
        "plan_counter"
    }

    fn k(&self) -> usize {
        self.plan.k()
    }

    fn plan(&self) -> Option<&ExecutionPlan> {
        Some(&self.plan)
    }

    fn run(&self, ctx: &mut WarpContext) {
        let k = self.plan.k();
        while ctx.control() {
            if ctx.extend_planned(&self.plan) {
                ctx.filter_plan(&self.plan);
                if ctx.te.len() == k - 1 {
                    ctx.aggregate_counter();
                }
            }
            ctx.move_(false);
        }
    }
}

/// `count_from` summed over every vertex: the CPU oracle for one member.
fn oracle(p: &ExecutionPlan, g: &dumato::graph::CsrGraph) -> u64 {
    (0..g.num_vertices() as u32).map(|v| p.count_from(g, v)).sum()
}

#[test]
fn fused_equals_sequential_planned_and_unplanned_property() {
    check(
        Config { cases: 8, ..Default::default() },
        "fused == sequential planned == unplanned across devices x strategies",
        |rng| {
            let n = rng.range(10, 16);
            let p = 0.2 + rng.f64() * 0.25;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6);
            let trie = PlanTrie::motifs(k);
            let oracles: Vec<u64> = trie.plans().iter().map(|pl| oracle(pl, &g)).collect();
            // the unplanned Algorithm-4 census is the third witness
            let unplanned = Runner::run(&g, &MotifCount::new(k), &cfg()).patterns;
            for devices in [1usize, 2] {
                for strategy in [
                    IntersectStrategy::Auto,
                    IntersectStrategy::Merge,
                    IntersectStrategy::Bisect,
                    IntersectStrategy::Bitmap,
                ] {
                    let mut c = cfg();
                    c.devices = devices;
                    c.intersect = strategy;
                    let r = Runner::run(&g, &MotifCount::planned(k), &c);
                    dumato::prop_assert_eq!(
                        &r.leaf_counts,
                        &oracles,
                        "leaf counts vs count_from: k={k} devices={devices} {strategy:?}"
                    );
                    dumato::prop_assert_eq!(
                        &r.patterns,
                        &unplanned,
                        "census vs unplanned: k={k} devices={devices} {strategy:?}"
                    );
                    dumato::prop_assert_eq!(
                        r.count,
                        oracles.iter().sum::<u64>(),
                        "total: k={k} devices={devices} {strategy:?}"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fused_leaves_match_the_sequential_planned_engine() {
    // the engine-vs-engine differential (not just the CPU oracle): each
    // leaf counter equals one full sequential planned run of that member
    let g = generators::erdos_renyi(16, 0.35, 21);
    let trie = PlanTrie::motifs(4);
    let fused = Runner::run(&g, &MotifCount::planned(4), &cfg());
    assert_eq!(fused.leaf_counts.len(), trie.num_patterns());
    for (i, pl) in trie.plans().iter().enumerate() {
        let seq = Runner::run(&g, &PlanCounter { plan: pl.clone() }, &cfg());
        assert_eq!(fused.leaf_counts[i], seq.count, "pattern {i}");
    }
}

#[test]
fn prefix_sharing_shrinks_the_interior() {
    // laid side by side the member plans hold plans.len() * (k - 2)
    // interior nodes (depths 1..k-1); the trie must merge some of them
    for k in [4usize, 5] {
        let trie = PlanTrie::motifs(k);
        let separate = trie.num_patterns() * (k - 2);
        assert!(
            trie.num_interior() < separate,
            "k={k}: {} interior nodes, separate plans hold {separate}",
            trie.num_interior()
        );
    }
}

#[test]
fn labeled_pattern_sets_count_exactly_across_devices() {
    let g = generators::with_random_labels(generators::erdos_renyi(18, 0.35, 13), 2, 7);
    let specs: Vec<String> = ["0:0-1:1,1:1-2:0", "0:1-1:1,1:1-2:1", "0:0-1:0,1:0-2:0,0:0-2:0"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let parsed = parse_pattern_set(&specs).unwrap();
    let qs = SubgraphQuerySet::for_graph(&parsed, &g).unwrap();
    let want: Vec<u64> =
        (0..qs.num_patterns()).map(|i| oracle(qs.member_plan(i), &g)).collect();
    for devices in [1usize, 2] {
        let mut c = cfg();
        c.devices = devices;
        let r = Runner::run(&g, &qs, &c);
        assert_eq!(qs.counts(&r), want, "devices={devices}");
    }
}

#[test]
fn trie_counts_survive_aggressive_load_balancing() {
    // an aggressive LB threshold forces many segment stops and donation
    // attempts; `seed_only` must keep trie warps from shipping TE
    // subtrees (whose walk position cannot move with them)
    let g = generators::erdos_renyi(40, 0.25, 17);
    let trie = PlanTrie::motifs(4);
    let want: Vec<u64> = trie.plans().iter().map(|pl| oracle(pl, &g)).collect();
    let lb = EngineConfig {
        warps: 8,
        threads: 2,
        ..Default::default()
    }
    .with_lb(LbConfig {
        threshold: 0.9,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let r = Runner::run(&g, &MotifCount::planned(4), &lb);
    assert_eq!(r.leaf_counts, want);
    // and the same under fleet epochs (inter-device donations)
    let mut fleet = lb.clone();
    fleet.devices = 2;
    let r2 = Runner::run(&g, &MotifCount::planned(4), &fleet);
    assert_eq!(r2.leaf_counts, want);
}
