//! Integration coverage for `apps::quasi_clique` — previously the only
//! app with zero integration tests. Pins down determinism across engine
//! configurations, multi-device agreement, and known counts on the
//! fixture generators.

use dumato::apps::{CliqueCount, QuasiCliqueCount};
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::multi::Partition;

fn cfg() -> EngineConfig {
    EngineConfig {
        warps: 8,
        threads: 2,
        ..Default::default()
    }
}

#[test]
fn determinism_across_engine_configurations() {
    // warp count, thread count, stealing, and layout must never move a
    // count — quasi-clique runs the unplanned enumerate-and-filter loop,
    // so this exercises the whole generic pipeline. gamma = 0.5 admits
    // every connected 4-subgraph (>= 3 of 6 edges), so the count is
    // guaranteed nonzero on the sparse skewed stand-in.
    let g = generators::CITESEER.scaled(0.1).generate(7);
    let algo = QuasiCliqueCount::new(4, 0.5);
    let want = Runner::run(&g, &algo, &cfg()).count;
    assert!(want > 0, "fixture too sparse to exercise anything");
    for (warps, threads, steal) in [(1, 1, true), (64, 4, true), (16, 2, false)] {
        let c = EngineConfig {
            warps,
            threads,
            steal,
            ..Default::default()
        };
        let got = Runner::run(&g, &algo, &c).count;
        assert_eq!(got, want, "warps={warps} threads={threads} steal={steal}");
    }
    // and the run is reproducible wholesale
    assert_eq!(Runner::run(&g, &algo, &cfg()).count, want);
}

#[test]
fn devices_agree_with_single_device() {
    let g = generators::erdos_renyi(60, 0.18, 13);
    let algo = QuasiCliqueCount::new(4, 0.5);
    let want = Runner::run(&g, &algo, &cfg()).count;
    for devices in [2, 3, 4] {
        for partition in [Partition::RoundRobin, Partition::DegreeAware] {
            let c = EngineConfig {
                warps: 16,
                threads: 2,
                devices,
                partition,
                ..Default::default()
            };
            let got = Runner::run(&g, &algo, &c).count;
            assert_eq!(got, want, "devices={devices} partition={partition:?}");
        }
    }
}

#[test]
fn known_counts_on_generators() {
    // complete graph: every k-subset has density 1, any gamma counts all
    let k7 = generators::complete(7);
    assert_eq!(Runner::run(&k7, &QuasiCliqueCount::new(4, 1.0), &cfg()).count, 35);
    assert_eq!(Runner::run(&k7, &QuasiCliqueCount::new(3, 0.7), &cfg()).count, 35);

    // cycle: connected 3-subgraphs are the n paths (2 of 3 edges, 0.667)
    let c12 = generators::cycle(12);
    assert_eq!(Runner::run(&c12, &QuasiCliqueCount::new(3, 0.6), &cfg()).count, 12);
    assert_eq!(Runner::run(&c12, &QuasiCliqueCount::new(3, 0.7), &cfg()).count, 0);

    // star: connected 3-subgraphs are the C(n,2) wedges
    let s8 = generators::star(8);
    assert_eq!(Runner::run(&s8, &QuasiCliqueCount::new(3, 0.0), &cfg()).count, 28);
    assert_eq!(Runner::run(&s8, &QuasiCliqueCount::new(3, 1.0), &cfg()).count, 0);

    // grid 2x3: 4-subgraph quasi-cliques at gamma 0.5 need >= 3 of 6
    // edges; the two unit squares have 4 edges, and gamma 0.7 (>= 5)
    // excludes everything (the grid is triangle-free)
    let g23 = generators::grid(2, 3);
    assert!(Runner::run(&g23, &QuasiCliqueCount::new(4, 0.5), &cfg()).count >= 2);
    assert_eq!(Runner::run(&g23, &QuasiCliqueCount::new(4, 0.7), &cfg()).count, 0);
}

#[test]
fn gamma_one_equals_planned_clique_on_a_standin() {
    // gamma = 1 quasi-cliques are cliques: the unplanned quasi-clique
    // pipeline must agree with the planned clique app on a Table III
    // stand-in (cross-path, cross-plan invariant)
    let g = generators::CITESEER.scaled(0.04).generate(2);
    for k in 3..=4 {
        let qc = Runner::run(&g, &QuasiCliqueCount::new(k, 1.0), &cfg()).count;
        let cl = Runner::run(&g, &CliqueCount::new(k), &cfg()).count;
        assert_eq!(qc, cl, "k={k}");
    }
}
