//! Load-balancing behaviour: the monitor/redistribute layer must engage on
//! skewed workloads, migrate work, and reduce the simulated critical path
//! (the paper's §IV-D/§V-A2 claims in miniature).

use dumato::apps::{CliqueCount, MotifCount};
use dumato::balance::LbConfig;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;

/// A workload with one huge hub: almost all work lands on a few seeds.
fn skewed_graph() -> dumato::graph::CsrGraph {
    generators::ASTROPH.scaled(0.06).generate(3)
}

#[test]
fn lb_engages_and_migrates_on_skewed_work() {
    let g = skewed_graph();
    let cfg = EngineConfig {
        warps: 256,
        threads: 4,
        ..Default::default()
    }
    .with_lb(LbConfig::clique());
    let r = Runner::run(&g, &CliqueCount::new(6), &cfg);
    assert!(r.metrics.segments > 1, "monitor never stopped the kernel");
    assert!(r.metrics.migrations > 0, "no traversals migrated");
    assert!(r.metrics.lb_overhead_seconds > 0.0);
}

#[test]
fn lb_reduces_critical_path_on_skewed_work() {
    // paper §V-A2: LB pays off as k grows and skew intensifies (and can
    // lose at small k — see lb_overhead_visible_on_tiny_work)
    let g = generators::ASTROPH.scaled(0.1).generate(3);
    let base = EngineConfig {
        warps: 256,
        threads: 4,
        ..Default::default()
    };
    let wc = Runner::run(&g, &CliqueCount::new(7), &base);
    let opt = Runner::run(
        &g,
        &CliqueCount::new(7),
        &base.clone().with_lb(LbConfig::clique()),
    );
    assert_eq!(wc.count, opt.count);
    // the paper's claim: with enough skew, DM_OPT < DM_WC
    assert!(
        opt.metrics.sim_seconds < wc.metrics.sim_seconds,
        "LB did not help: {} vs {}",
        opt.metrics.sim_seconds,
        wc.metrics.sim_seconds
    );
}

#[test]
fn lb_overhead_visible_on_tiny_work() {
    // the paper's counter-claim: for trivial workloads LB is not free
    let g = generators::cycle(64);
    let base = EngineConfig {
        warps: 16,
        threads: 2,
        ..Default::default()
    };
    let wc = Runner::run(&g, &CliqueCount::new(3), &base);
    let opt = Runner::run(
        &g,
        &CliqueCount::new(3),
        &base.clone().with_lb(LbConfig::clique()),
    );
    assert_eq!(wc.count, opt.count);
    assert_eq!(wc.count, 0);
    // no assertion that opt is slower (it may be equal when the monitor
    // never fires) — only that both terminate and agree
}

#[test]
fn motif_lb_with_low_threshold() {
    let g = generators::ASTROPH.scaled(0.04).generate(5);
    let base = EngineConfig {
        warps: 128,
        threads: 4,
        ..Default::default()
    };
    let wc = Runner::run(&g, &MotifCount::new(4), &base);
    let opt = Runner::run(
        &g,
        &MotifCount::new(4),
        &base.clone().with_lb(LbConfig::motif()),
    );
    assert_eq!(wc.patterns, opt.patterns);
}

#[test]
fn checkpoint_resume_preserves_deep_state() {
    // force many tiny segments with an aggressive threshold: every stop
    // checkpoints mid-enumeration TEs and the final counts must still be
    // exact (the "consistent state" property of Fig 5 step 3)
    let g = skewed_graph();
    let reference = Runner::run(
        &g,
        &CliqueCount::new(5),
        &EngineConfig {
            warps: 64,
            threads: 4,
            ..Default::default()
        },
    )
    .count;
    let aggressive = EngineConfig {
        warps: 64,
        threads: 4,
        ..Default::default()
    }
    .with_lb(LbConfig {
        threshold: 0.95,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let r = Runner::run(&g, &CliqueCount::new(5), &aggressive);
    assert_eq!(r.count, reference);
    assert!(r.metrics.segments >= 2);
}

#[test]
fn lb_on_off_counts_invariant_property() {
    // randomized version of the paper's correctness contract: the LB layer
    // (any threshold, stealing on or off) must never change exact counts
    use dumato::util::proptest::{check, Config};
    check(
        Config { cases: 10, ..Default::default() },
        "engine counts invariant under lb Some/None x steal on/off",
        |rng| {
            let n = rng.range(16, 40);
            let p = 0.15 + rng.f64() * 0.3;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6);
            let base = EngineConfig {
                warps: 16,
                threads: 3,
                ..Default::default()
            };
            let reference = Runner::run(&g, &CliqueCount::new(k), &base).count;
            let threshold = 0.05 + rng.f64() * 0.9;
            let mut cfg = base.clone().with_lb(
                LbConfig {
                    threshold,
                    poll_interval: std::time::Duration::from_micros(100),
                },
            );
            cfg.steal = rng.chance(0.5);
            let lb = Runner::run(&g, &CliqueCount::new(k), &cfg);
            dumato::prop_assert_eq!(
                reference,
                lb.count,
                "n={n} p={p:.2} k={k} thr={threshold:.2} steal={}",
                cfg.steal
            );
            Ok(())
        },
    );
}
