//! Load-balancing behaviour: the monitor/redistribute layer must engage on
//! skewed workloads, migrate work, and reduce the simulated critical path
//! (the paper's §IV-D/§V-A2 claims in miniature).

use dumato::apps::{CliqueCount, MotifCount};
use dumato::balance::LbConfig;
use dumato::engine::{EngineConfig, Runner, WarpState};
use dumato::graph::generators;
use dumato::multi::{rebalance_fleet, Partition};

/// A workload with one huge hub: almost all work lands on a few seeds.
fn skewed_graph() -> dumato::graph::CsrGraph {
    generators::ASTROPH.scaled(0.06).generate(3)
}

#[test]
fn lb_engages_and_migrates_on_skewed_work() {
    let g = skewed_graph();
    let cfg = EngineConfig {
        warps: 256,
        threads: 4,
        ..Default::default()
    }
    .with_lb(LbConfig::clique());
    let r = Runner::run(&g, &CliqueCount::new(6), &cfg);
    assert!(r.metrics.segments > 1, "monitor never stopped the kernel");
    assert!(r.metrics.migrations > 0, "no traversals migrated");
    assert!(r.metrics.lb_overhead_seconds > 0.0);
}

#[test]
fn lb_reduces_critical_path_on_skewed_work() {
    // paper §V-A2: LB pays off as k grows and skew intensifies (and can
    // lose at small k — see lb_overhead_visible_on_tiny_work)
    let g = generators::ASTROPH.scaled(0.1).generate(3);
    let base = EngineConfig {
        warps: 256,
        threads: 4,
        ..Default::default()
    };
    let wc = Runner::run(&g, &CliqueCount::new(7), &base);
    let opt = Runner::run(
        &g,
        &CliqueCount::new(7),
        &base.clone().with_lb(LbConfig::clique()),
    );
    assert_eq!(wc.count, opt.count);
    // the paper's claim: with enough skew, DM_OPT < DM_WC
    assert!(
        opt.metrics.sim_seconds < wc.metrics.sim_seconds,
        "LB did not help: {} vs {}",
        opt.metrics.sim_seconds,
        wc.metrics.sim_seconds
    );
}

#[test]
fn lb_overhead_visible_on_tiny_work() {
    // the paper's counter-claim: for trivial workloads LB is not free
    let g = generators::cycle(64);
    let base = EngineConfig {
        warps: 16,
        threads: 2,
        ..Default::default()
    };
    let wc = Runner::run(&g, &CliqueCount::new(3), &base);
    let opt = Runner::run(
        &g,
        &CliqueCount::new(3),
        &base.clone().with_lb(LbConfig::clique()),
    );
    assert_eq!(wc.count, opt.count);
    assert_eq!(wc.count, 0);
    // no assertion that opt is slower (it may be equal when the monitor
    // never fires) — only that both terminate and agree
}

#[test]
fn motif_lb_with_low_threshold() {
    let g = generators::ASTROPH.scaled(0.04).generate(5);
    let base = EngineConfig {
        warps: 128,
        threads: 4,
        ..Default::default()
    };
    let wc = Runner::run(&g, &MotifCount::new(4), &base);
    let opt = Runner::run(
        &g,
        &MotifCount::new(4),
        &base.clone().with_lb(LbConfig::motif()),
    );
    assert_eq!(wc.patterns, opt.patterns);
}

#[test]
fn checkpoint_resume_preserves_deep_state() {
    // force many tiny segments with an aggressive threshold: every stop
    // checkpoints mid-enumeration TEs and the final counts must still be
    // exact (the "consistent state" property of Fig 5 step 3)
    let g = skewed_graph();
    let reference = Runner::run(
        &g,
        &CliqueCount::new(5),
        &EngineConfig {
            warps: 64,
            threads: 4,
            ..Default::default()
        },
    )
    .count;
    let aggressive = EngineConfig {
        warps: 64,
        threads: 4,
        ..Default::default()
    }
    .with_lb(LbConfig {
        threshold: 0.95,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let r = Runner::run(&g, &CliqueCount::new(5), &aggressive);
    assert_eq!(r.count, reference);
    assert!(r.metrics.segments >= 2);
}

#[test]
fn device_count_invariance_property() {
    // the multi-device contract: exact counts from the apps are identical
    // for devices in {1, 2, 4} x steal on/off x both partition policies
    // (devices = 1 is the classic single-device path, cross-validating
    // the fleet against the original engine)
    use dumato::util::proptest::{check, Config};
    check(
        Config { cases: 6, ..Default::default() },
        "app counts invariant under devices x steal x partition",
        |rng| {
            let n = rng.range(16, 36);
            let p = 0.15 + rng.f64() * 0.3;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6);
            let base = EngineConfig {
                warps: 8,
                threads: 2,
                ..Default::default()
            };
            let want_clique = Runner::run(&g, &CliqueCount::new(k), &base).count;
            let want_motif = Runner::run(&g, &MotifCount::new(4), &base).patterns;
            for devices in [1usize, 2, 4] {
                for steal in [true, false] {
                    for partition in [Partition::RoundRobin, Partition::DegreeAware] {
                        let mut cfg = base.clone();
                        cfg.devices = devices;
                        cfg.steal = steal;
                        cfg.partition = partition;
                        let got = Runner::run(&g, &CliqueCount::new(k), &cfg).count;
                        dumato::prop_assert_eq!(
                            want_clique,
                            got,
                            "clique n={n} p={p:.2} k={k} devices={devices} steal={steal} {partition:?}"
                        );
                        let got_m = Runner::run(&g, &MotifCount::new(4), &cfg).patterns;
                        dumato::prop_assert_eq!(
                            &want_motif,
                            &got_m,
                            "motif n={n} p={p:.2} devices={devices} steal={steal} {partition:?}"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

/// Every pending unit of work across the whole fleet, as the seed each
/// unit would become if donated: queued seeds plus each live extension e
/// at TE level l expanded to `tr[0..=l] ++ [e]` (the same expansion the
/// intra-device property in `balance::redistribute` uses).
fn fleet_work_multiset(devices: &[Vec<WarpState>]) -> Vec<Vec<u32>> {
    let mut units: Vec<Vec<u32>> = Vec::new();
    for w in devices.iter().flatten() {
        units.extend(w.queue.iter().cloned());
        for l in 0..w.te.len() {
            for &e in w.te.ext_slice(l) {
                if e != dumato::engine::INVALID_V {
                    let mut s = w.te.traversal()[..=l].to_vec();
                    s.push(e);
                    units.push(s);
                }
            }
        }
    }
    units.sort_unstable();
    units
}

#[test]
fn fleet_rebalance_preserves_cross_device_work_multiset() {
    // inter-device donation must never lose, duplicate, or rewrite a unit
    // of pending work, across randomized device states
    use dumato::util::proptest::{check, Config};
    check(
        Config { cases: 32, ..Default::default() },
        "inter-device donation preserves the fleet work multiset",
        |rng| {
            let gn = rng.range(12, 30);
            let g = generators::erdos_renyi(gn, 0.3, rng.next_u64());
            let k = rng.range(4, 7);
            let ndev = rng.range(2, 6);
            let mut devices: Vec<Vec<WarpState>> = (0..ndev)
                .map(|_| {
                    let nw = rng.range(1, 5);
                    (0..nw)
                        .map(|i| {
                            let mut w = WarpState::new(i, k);
                            if rng.chance(0.4) {
                                w.finished = true;
                                return w;
                            }
                            for _ in 0..rng.range(0, 4) {
                                w.queue.push_back(vec![rng.range(0, gn) as u32]);
                            }
                            if rng.chance(0.5) {
                                let plen = rng.range(1, k - 1);
                                let start = rng.range(0, gn);
                                let seed: Vec<u32> =
                                    (0..plen).map(|j| ((start + j) % gn) as u32).collect();
                                w.te.init_from_seed(&seed, &g, false);
                                for l in 0..plen {
                                    if rng.chance(0.6) {
                                        let m = rng.range(0, 5);
                                        let items: Vec<u32> = (0..m)
                                            .map(|_| {
                                                if rng.chance(0.2) {
                                                    dumato::engine::INVALID_V
                                                } else {
                                                    rng.range(0, gn) as u32
                                                }
                                            })
                                            .collect();
                                        w.te.set_ext(l, &items);
                                        w.te.set_generated(l, true);
                                    }
                                }
                            }
                            if !w.has_work() {
                                w.finished = true;
                            }
                            w
                        })
                        .collect()
                })
                .collect();
            let before = fleet_work_multiset(&devices);
            let xfer = rebalance_fleet(&mut devices);
            let after = fleet_work_multiset(&devices);
            dumato::prop_assert_eq!(&before, &after, "fleet work multiset changed");
            for (d, ws) in devices.iter().enumerate() {
                for w in ws {
                    dumato::prop_assert!(
                        w.finished || w.has_work(),
                        "device {d} warp {} active without work",
                        w.id
                    );
                }
            }
            // bytes are consistent with what moved: every migrated unit is
            // a non-empty prefix, so bytes >= 4 * migrations
            dumato::prop_assert!(
                xfer.bytes >= 4 * xfer.migrations,
                "bytes {} < 4 * migrations {}",
                xfer.bytes,
                xfer.migrations
            );
            Ok(())
        },
    );
}

#[test]
fn lb_on_off_counts_invariant_property() {
    // randomized version of the paper's correctness contract: the LB layer
    // (any threshold, stealing on or off) must never change exact counts
    use dumato::util::proptest::{check, Config};
    check(
        Config { cases: 10, ..Default::default() },
        "engine counts invariant under lb Some/None x steal on/off",
        |rng| {
            let n = rng.range(16, 40);
            let p = 0.15 + rng.f64() * 0.3;
            let g = generators::erdos_renyi(n, p, rng.next_u64());
            let k = rng.range(3, 6);
            let base = EngineConfig {
                warps: 16,
                threads: 3,
                ..Default::default()
            };
            let reference = Runner::run(&g, &CliqueCount::new(k), &base).count;
            let threshold = 0.05 + rng.f64() * 0.9;
            let mut cfg = base.clone().with_lb(
                LbConfig {
                    threshold,
                    poll_interval: std::time::Duration::from_micros(100),
                },
            );
            cfg.steal = rng.chance(0.5);
            let lb = Runner::run(&g, &CliqueCount::new(k), &cfg);
            dumato::prop_assert_eq!(
                reference,
                lb.count,
                "n={n} p={p:.2} k={k} thr={threshold:.2} steal={}",
                cfg.steal
            );
            Ok(())
        },
    );
}
