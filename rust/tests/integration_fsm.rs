//! Differential tests for frequent subgraph mining (ISSUE 9): the
//! engine-backed miner must produce the exact frequent-pattern set of
//! a naive CPU oracle.
//!
//! - `fsm::mine` == `fsm::oracle_frequent` (keys AND supports) over
//!   random labeled G(n,p) graphs x label cardinalities x support
//!   thresholds x max sizes <= 4;
//! - single-device and 2-device fleets agree;
//! - at support 1 on a single-label graph, the frequent k-patterns are
//!   exactly the patterns embeddable in some induced connected
//!   k-subgraph of the census (the non-induced existence closure);
//! - results are bit-identical across warp counts and scheduler
//!   stealing (determinism of the domain reduction).

use std::sync::Arc;

use dumato::apps::fsm::{mine, oracle_frequent, FsmConfig};
use dumato::apps::MotifCount;
use dumato::canon::bitmap::AdjMat;
use dumato::canon::canonical::for_each_permutation;
use dumato::canon::patterns::all_patterns;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, CsrGraph, Label};
use dumato::util::Rng;

fn cfg(devices: usize) -> EngineConfig {
    EngineConfig {
        warps: 16,
        threads: 2,
        devices,
        ..EngineConfig::default()
    }
}

fn labeled_er(n: usize, p: f64, cardinality: u64, seed: u64) -> Arc<CsrGraph> {
    let g = generators::erdos_renyi(n, p, seed);
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    let labels: Vec<Label> = (0..n).map(|_| (rng.next_u64() % cardinality) as Label).collect();
    Arc::new(g.with_labels(labels).unwrap())
}

#[test]
fn mine_equals_oracle_over_random_labeled_graphs() {
    for seed in [2u64, 9, 31] {
        for cardinality in [1u64, 2, 3] {
            let g = labeled_er(12, 0.3, cardinality, seed);
            for support in [1u64, 2, 3] {
                for max_size in [3usize, 4] {
                    let r = mine(
                        &g,
                        &FsmConfig { support, max_size, fuse: true, engine: cfg(1) },
                    );
                    assert!(!r.timed_out && r.fault.is_none());
                    assert_eq!(
                        r.keys_with_support(),
                        oracle_frequent(&g, support, max_size),
                        "seed={seed} card={cardinality} support={support} max_size={max_size}"
                    );
                }
            }
        }
    }
}

#[test]
fn sequential_candidates_match_fused_rounds() {
    let g = labeled_er(13, 0.3, 2, 5);
    let fused = mine(&g, &FsmConfig { support: 2, max_size: 4, fuse: true, engine: cfg(1) });
    let seq = mine(&g, &FsmConfig { support: 2, max_size: 4, fuse: false, engine: cfg(1) });
    assert_eq!(fused.keys_with_support(), seq.keys_with_support());
    assert!(
        fused.engine_runs() <= seq.engine_runs(),
        "fusion cannot take more engine runs ({} vs {})",
        fused.engine_runs(),
        seq.engine_runs()
    );
}

#[test]
fn device_fleet_agrees_with_single_device() {
    for (cardinality, support) in [(1u64, 2u64), (2, 1), (3, 2)] {
        let g = labeled_er(13, 0.3, cardinality, 7 + cardinality);
        let one = mine(&g, &FsmConfig { support, max_size: 4, fuse: true, engine: cfg(1) });
        let two = mine(&g, &FsmConfig { support, max_size: 4, fuse: true, engine: cfg(2) });
        assert_eq!(
            one.keys_with_support(),
            two.keys_with_support(),
            "card={cardinality} support={support}"
        );
    }
}

/// Does `p` embed (non-induced) into `q` — both k-vertex patterns?
fn embeds_in(p: &AdjMat, q: &AdjMat) -> bool {
    let k = p.k;
    let mut found = false;
    for_each_permutation(k, |perm| {
        if found {
            return;
        }
        let pp = p.permute(perm);
        let mut sub = true;
        'scan: for a in 0..k {
            for b in (a + 1)..k {
                if pp.has_edge(a, b) && !q.has_edge(a, b) {
                    sub = false;
                    break 'scan;
                }
            }
        }
        found |= sub;
    });
    found
}

#[test]
fn support_one_single_label_is_the_noninduced_closure_of_the_census() {
    let g = labeled_er(12, 0.35, 1, 13);
    let r = mine(&g, &FsmConfig { support: 1, max_size: 4, fuse: true, engine: cfg(1) });
    for k in [3usize, 4] {
        // induced census from the motif app (the unrelated reference path)
        let census = Runner::run(&g, &MotifCount::new(k), &cfg(1));
        let present: Vec<AdjMat> = all_patterns(k)
            .into_iter()
            .filter(|m| {
                let bm = dumato::canon::canonical::canonical_form(m);
                census.patterns.iter().any(|&(b, c)| b == bm && c > 0)
            })
            .collect();
        // a pattern has a non-induced embedding iff it embeds in some
        // induced connected k-subgraph that actually occurs
        let mined: Vec<u64> = r
            .frequent
            .iter()
            .filter(|f| f.adj.k == k)
            .map(|f| f.key.bitmap)
            .collect();
        for m in all_patterns(k) {
            let want = present.iter().any(|q| embeds_in(&m, q));
            let bm = dumato::plan::pattern_key(&m, Some(&vec![0; k])).bitmap;
            assert_eq!(
                mined.contains(&bm),
                want,
                "k={k} bitmap={bm:#x} (census closure disagrees)"
            );
        }
    }
}

#[test]
fn results_are_deterministic_across_warps_and_stealing() {
    let g = labeled_er(14, 0.3, 2, 21);
    let base = mine(&g, &FsmConfig { support: 2, max_size: 4, fuse: true, engine: cfg(1) });
    for (warps, steal) in [(4usize, true), (32, true), (16, false)] {
        let engine = EngineConfig { warps, steal, ..cfg(1) };
        let r = mine(&g, &FsmConfig { support: 2, max_size: 4, fuse: true, engine });
        assert_eq!(
            base.keys_with_support(),
            r.keys_with_support(),
            "warps={warps} steal={steal}"
        );
        // embeddings (raw ordered match counts) must be deterministic too
        let e0: Vec<u64> = base.frequent.iter().map(|f| f.embeddings).collect();
        let e1: Vec<u64> = r.frequent.iter().map(|f| f.embeddings).collect();
        assert_eq!(e0, e1, "warps={warps} steal={steal}");
    }
}
