//! Differential tests for the dynamic-graph layer (ISSUE 8): every
//! incremental path must be indistinguishable from recomputation.
//!
//! - `count_delta` over a committed update batch must equal the full
//!   recount difference for k in {3,4,5} patterns, labeled and
//!   unlabeled, across 1- and 2-device engines and every set-
//!   intersection strategy;
//! - `CoreTracker` must agree with a fresh `core_numbers` peel after
//!   every batch of a random insert/delete stream;
//! - `reorient` must reuse the old permutation under the churn
//!   threshold (still a valid orientation: oriented counts match) and
//!   be bit-identical to a fresh degeneracy peel past it;
//! - the in-process service handle must adjust cached counts across
//!   repeated UPDATE+COMMIT rounds without ever serving a stale count.

use std::sync::Arc;

use dumato::apps::{count_delta, CliqueCount, SubgraphQuery};
use dumato::canon::bitmap::AdjMat;
use dumato::engine::{EngineConfig, IntersectStrategy, Runner};
use dumato::graph::delta::{reorient, CoreTracker, EdgeOp, DEFAULT_REORIENT_CHURN};
use dumato::graph::ordering::{core_numbers, degeneracy_peel, orient, relabel};
use dumato::graph::{generators, CsrGraph, GraphStore, VertexId};
use dumato::plan::ExecutionPlan;
use dumato::util::Rng;

fn cfg(devices: usize, intersect: IntersectStrategy) -> EngineConfig {
    EngineConfig {
        warps: 32,
        threads: 2,
        devices,
        intersect,
        ..EngineConfig::default()
    }
}

/// Pattern pool spanning k in {3,4,5}: (name, edge list).
fn patterns() -> Vec<(&'static str, Vec<(usize, usize)>)> {
    vec![
        ("triangle", vec![(0, 1), (1, 2), (2, 0)]),
        ("wedge", vec![(0, 1), (1, 2)]),
        ("4-cycle", vec![(0, 1), (1, 2), (2, 3), (3, 0)]),
        ("4-path", vec![(0, 1), (1, 2), (2, 3)]),
        ("diamond", vec![(0, 1), (1, 2), (2, 0), (0, 3), (2, 3)]),
        ("5-path", vec![(0, 1), (1, 2), (2, 3), (3, 4)]),
        ("5-star", vec![(0, 1), (0, 2), (0, 3), (0, 4)]),
    ]
}

fn adj_of(edges: &[(usize, usize)]) -> (usize, AdjMat) {
    let k = edges.iter().map(|&(a, b)| a.max(b)).max().unwrap() + 1;
    let mut m = AdjMat::empty(k);
    for &(a, b) in edges {
        m.set_edge(a, b);
    }
    (k, m)
}

/// Full-recount oracle (match count, not embeddings).
fn recount(g: &CsrGraph, edges: &[(usize, usize)], labels: Option<&[u32]>, c: &EngineConfig) -> i64 {
    let (k, _) = adj_of(edges);
    let q = match labels {
        Some(ls) => SubgraphQuery::labeled_for(k, edges, ls, g),
        None => SubgraphQuery::new(k, edges),
    };
    let r = Runner::run(g, &q, c);
    assert!(!r.timed_out && r.fault.is_none());
    q.matches(&r).len() as i64
}

/// Stage a deterministic mixed batch (`ni` inserts, `nd` deletes) and
/// commit it, returning both snapshots plus the frontier.
fn committed_batch(
    store: &GraphStore,
    ni: usize,
    nd: usize,
    seed: u64,
) -> (Arc<CsrGraph>, Arc<CsrGraph>, Arc<dumato::graph::FrontierSet>) {
    let base = store.snapshot().graph;
    let n = base.num_vertices() as u64;
    let mut rng = Rng::new(seed);
    let mut b = store.begin_update();
    while b.inserts().len() < ni {
        let u = rng.below(n) as VertexId;
        let v = rng.below(n) as VertexId;
        if u != v && !base.has_edge(u, v) {
            let _ = b.stage(EdgeOp::Insert(u, v));
        }
    }
    let edges: Vec<(VertexId, VertexId)> = base.edges().collect();
    let mut idx: Vec<usize> = (0..edges.len()).collect();
    rng.shuffle(&mut idx);
    for &i in idx.iter().take(nd) {
        let (u, v) = edges[i];
        let _ = b.stage(EdgeOp::Delete(u, v));
    }
    assert!(b.len() >= ni, "batch staging drifted");
    let frontier = Arc::new(b.frontier());
    let c = store.commit(b).unwrap();
    (c.old.graph, c.new.graph, frontier)
}

#[test]
fn incremental_counts_match_recount_across_devices_and_strategies() {
    let store = GraphStore::new(Arc::new(generators::erdos_renyi(26, 0.22, 31)));
    let (old, new, frontier) = committed_batch(&store, 3, 2, 0xd1f);
    for devices in [1usize, 2] {
        for strategy in [
            IntersectStrategy::Auto,
            IntersectStrategy::Merge,
            IntersectStrategy::Bisect,
            IntersectStrategy::Bitmap,
        ] {
            let c = cfg(devices, strategy);
            for (name, edges) in patterns() {
                let (_, m) = adj_of(&edges);
                let plan = ExecutionPlan::build(&m);
                let r = count_delta(&old, &new, &frontier, &plan, &c);
                assert!(r.clean, "{name} devices={devices} {strategy:?}");
                let want = recount(&new, &edges, None, &c) - recount(&old, &edges, None, &c);
                assert_eq!(
                    r.delta, want,
                    "{name} devices={devices} {strategy:?}: delta != recount diff"
                );
            }
        }
    }
}

#[test]
fn incremental_counts_match_recount_on_labeled_patterns() {
    let store = GraphStore::new(Arc::new(generators::with_random_labels(
        generators::erdos_renyi(28, 0.22, 57),
        3,
        11,
    )));
    let freq = store.snapshot().graph.label_frequencies();
    let (old, new, frontier) = committed_batch(&store, 3, 2, 0xab1e);
    let c = cfg(1, IntersectStrategy::Auto);
    // every distinct label assignment of the wedge and triangle over 2
    // of the 3 graph labels, plus a 4-path with a repeated label
    let labeled: Vec<(&str, Vec<(usize, usize)>, Vec<u32>)> = vec![
        ("wedge-010", vec![(0, 1), (1, 2)], vec![0, 1, 0]),
        ("wedge-120", vec![(0, 1), (1, 2)], vec![1, 2, 0]),
        ("tri-001", vec![(0, 1), (1, 2), (2, 0)], vec![0, 0, 1]),
        ("tri-012", vec![(0, 1), (1, 2), (2, 0)], vec![0, 1, 2]),
        ("4path-0110", vec![(0, 1), (1, 2), (2, 3)], vec![0, 1, 1, 0]),
    ];
    for (name, edges, labels) in labeled {
        let (_, m) = adj_of(&edges);
        let plan = ExecutionPlan::build_labeled(&m, &labels, Some(&freq));
        let r = count_delta(&old, &new, &frontier, &plan, &c);
        assert!(r.clean, "{name}");
        let want = recount(&new, &edges, Some(&labels), &c) - recount(&old, &edges, Some(&labels), &c);
        assert_eq!(r.delta, want, "{name}: labeled delta != recount diff");
    }
}

#[test]
fn core_tracker_matches_fresh_peel_across_a_random_stream() {
    let store = GraphStore::new(Arc::new(generators::erdos_renyi(60, 0.12, 5)));
    let mut tracker = CoreTracker::new(&store.snapshot().graph);
    let mut rng = Rng::new(0xc0de);
    for round in 0..6 {
        let base = store.snapshot().graph;
        let n = base.num_vertices() as u64;
        let mut b = store.begin_update();
        let mut staged = 0;
        while staged < 8 {
            let u = rng.below(n) as VertexId;
            let v = rng.below(n) as VertexId;
            if u == v {
                continue;
            }
            let op = if base.has_edge(u, v) {
                EdgeOp::Delete(u, v)
            } else {
                EdgeOp::Insert(u, v)
            };
            if b.stage(op).is_ok() {
                staged += 1;
            }
        }
        tracker.apply_batch(&b);
        let c = store.commit(b).unwrap();
        assert_eq!(
            tracker.cores(),
            core_numbers(&c.new.graph).as_slice(),
            "round {round}: incremental cores drifted from the fresh peel"
        );
        tracker.clear_touched();
    }
}

/// Structural graph equality (CsrGraph carries no `PartialEq`).
fn assert_same_graph(a: &CsrGraph, b: &CsrGraph, what: &str) {
    assert_eq!(a.num_vertices(), b.num_vertices(), "{what}: |V|");
    assert_eq!(a.is_directed(), b.is_directed(), "{what}: directedness");
    for v in 0..a.num_vertices() as VertexId {
        assert_eq!(a.neighbors(v), b.neighbors(v), "{what}: adjacency of {v}");
    }
}

#[test]
fn reorient_reuses_perm_under_churn_and_matches_fresh_peel_past_it() {
    let store = GraphStore::new(Arc::new(generators::erdos_renyi(40, 0.15, 21)));
    let (perm0, _) = degeneracy_peel(&store.snapshot().graph);
    let (_, new, frontier) = committed_batch(&store, 4, 2, 0x0e0);
    let c = cfg(1, IntersectStrategy::Auto);
    let undirected_triangles = recount(&new, &[(0, 1), (1, 2), (2, 0)], None, &c);

    // small churn: permutation reused, and the result is still a valid
    // orientation — oriented clique counts agree with the undirected
    // oracle on the same snapshot (report 4 touched vertices, well
    // under the 0.25 threshold on |V| = 40; the frontier itself can
    // reach 12 endpoints, which would tip over it)
    let _ = frontier;
    let low = reorient(&new, &perm0, 4, DEFAULT_REORIENT_CHURN);
    assert!(!low.full, "churn {} must reuse the perm", low.churn);
    assert_eq!(low.perm, perm0);
    let r = Runner::run(&low.graph, &CliqueCount::oriented(3), &c);
    assert!(!r.timed_out && r.fault.is_none());
    assert_eq!(r.count as i64, undirected_triangles, "reused-perm orientation miscounts");

    // past the threshold: bit-identical to the fresh peel + orient
    let high = reorient(&new, &perm0, new.num_vertices(), DEFAULT_REORIENT_CHURN);
    assert!(high.full, "churn {} must force a fresh peel", high.churn);
    let (fresh_perm, _) = degeneracy_peel(&new);
    assert_eq!(high.perm, fresh_perm);
    assert_same_graph(
        &high.graph,
        &orient(&relabel(&new, &fresh_perm)),
        "full reorient",
    );
    let r = Runner::run(&high.graph, &CliqueCount::oriented(3), &c);
    assert_eq!(r.count as i64, undirected_triangles);
}

#[test]
fn service_adjusts_cached_counts_across_repeated_commits() {
    use dumato::service::{Service, ServiceConfig};
    let g = generators::erdos_renyi(24, 0.25, 77);
    let svc = Service::open(
        GraphStore::new(Arc::new(g)),
        ServiceConfig {
            engine: cfg(1, IntersectStrategy::Auto),
            batch_window: std::time::Duration::from_millis(1),
            ..ServiceConfig::default()
        },
    );
    let h = svc.handle();
    let specs: Vec<String> = ["0-1,1-2,2-0", "0-1,1-2,2-3", "0-1,0-2,0-3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut rng = Rng::new(0x5eed);
    for round in 0..3u64 {
        // warm the cache on the current snapshot
        for s in &specs {
            h.query(&[s.clone()]).unwrap();
        }
        // one random insert + one random delete through the handle
        let base = h.graph();
        let n = base.num_vertices() as u64;
        let ins = loop {
            let u = rng.below(n) as VertexId;
            let v = rng.below(n) as VertexId;
            if u != v && !base.has_edge(u, v) {
                break (u, v);
            }
        };
        let del = {
            let edges: Vec<(VertexId, VertexId)> = base.edges().collect();
            edges[rng.below(edges.len() as u64) as usize]
        };
        h.stage_updates(&[format!("+{},{}", ins.0, ins.1), format!("-{},{}", del.0, del.1)])
            .unwrap();
        let outcome = h.commit_updates().unwrap();
        assert_eq!(outcome.epoch, round + 1);
        assert_eq!(
            outcome.adjusted + outcome.invalidated,
            specs.len(),
            "every warm entry is either adjusted or invalidated"
        );
        // post-commit answers must equal fresh recounts on the new
        // snapshot — and an unchanged-count pattern must still have
        // been *re-tagged*, never served from the old epoch
        let post = h.graph();
        for (i, s) in specs.iter().enumerate() {
            let o = h.query(&[s.clone()]).unwrap();
            let edges: Vec<(usize, usize)> = match i {
                0 => vec![(0, 1), (1, 2), (2, 0)],
                1 => vec![(0, 1), (1, 2), (2, 3)],
                _ => vec![(0, 1), (0, 2), (0, 3)],
            };
            let want = recount(&post, &edges, None, &cfg(1, IntersectStrategy::Auto)) as u64;
            assert_eq!(o.counts[0], want, "round {round} spec {s}: stale or wrong count");
        }
    }
    let s = h.stats();
    assert_eq!(s.commits, 3);
    assert_eq!(s.epoch, 3);
    svc.shutdown();
}
