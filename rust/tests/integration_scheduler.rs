//! Scheduler-layer acceptance: the worker pool is persistent (one spawn
//! per run, however many LB segments execute) and dynamic warp-slot
//! stealing keeps threads busy on skewed seed distributions where the old
//! static `chunks_mut` partitioning idles.

use dumato::apps::CliqueCount;
use dumato::balance::LbConfig;
use dumato::baselines::enumerate::cliques_from;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, CsrGraph};

/// A deliberately skewed seed distribution for `warps` virtual warps:
/// seeds are dealt round-robin by vertex id, so a clique laid out on ids
/// that are all ≡ 0 (mod warps) lands its entire heavy workload in warp
/// 0's queue while every other warp gets only pendant leaves.
fn skewed_deal_graph(warps: usize, clique: usize) -> CsrGraph {
    let n = warps * clique;
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let members: Vec<u32> = (0..clique).map(|i| (i * warps) as u32).collect();
    for (i, &u) in members.iter().enumerate() {
        for &v in &members[i + 1..] {
            adj[u as usize].push(v);
        }
    }
    // pendant leaves keep every other vertex a (trivial) seed
    for v in 0..n as u32 {
        if v as usize % warps != 0 {
            adj[v as usize].push(members[v as usize % clique]);
        }
    }
    CsrGraph::from_adjacency(adj, "skewed-deal")
}

fn brute_reference(g: &CsrGraph, k: usize) -> u64 {
    (0..g.num_vertices() as u32).map(|v| cliques_from(g, v, k)).sum()
}

#[test]
fn stealing_beats_static_partitioning_on_skewed_deal() {
    // K20 on warp-0 seeds: enough work that warp 0 spans many quanta
    // while every other warp drains almost immediately
    let g = skewed_deal_graph(8, 20);
    let k = 6;
    let expect = brute_reference(&g, k);

    let base = EngineConfig {
        warps: 8,
        threads: 4,
        ..Default::default()
    };
    let stealing = Runner::run(&g, &CliqueCount::new(k), &EngineConfig { steal: true, ..base.clone() });
    let static_ = Runner::run(&g, &CliqueCount::new(k), &EngineConfig { steal: false, ..base });

    assert_eq!(stealing.count, expect);
    assert_eq!(static_.count, expect);
    // the acceptance criterion: stealing shows fewer idle-thread segments
    // than the static-chunking baseline on a skewed deal
    assert!(
        static_.metrics.idle_worker_segments > stealing.metrics.idle_worker_segments,
        "static idle {} must exceed stealing idle {}",
        static_.metrics.idle_worker_segments,
        stealing.metrics.idle_worker_segments
    );
    assert_eq!(stealing.metrics.idle_worker_segments, 0);
}

#[test]
fn worker_pool_is_spawned_once_per_run() {
    // force several LB stops; the pool must not respawn per segment
    let g = generators::ASTROPH.scaled(0.06).generate(3);
    let cfg = EngineConfig {
        warps: 64,
        threads: 3,
        ..Default::default()
    }
    .with_lb(LbConfig {
        threshold: 0.9,
        poll_interval: std::time::Duration::from_micros(50),
    });
    let r = Runner::run(&g, &CliqueCount::new(5), &cfg);
    assert!(r.metrics.segments >= 2, "expected LB stops, got 1 segment");
    assert_eq!(
        r.metrics.thread_spawns, 3,
        "threads spawned must equal the pool size regardless of {} segments",
        r.metrics.segments
    );
}

#[test]
fn one_thread_and_many_threads_agree_with_stealing_on_and_off() {
    let g = generators::barabasi_albert(70, 4, 11);
    let reference = Runner::run(
        &g,
        &CliqueCount::new(4),
        &EngineConfig { warps: 1, threads: 1, ..Default::default() },
    )
    .count;
    for steal in [false, true] {
        for (warps, threads) in [(7, 3), (64, 8)] {
            let c = Runner::run(
                &g,
                &CliqueCount::new(4),
                &EngineConfig { warps, threads, steal, ..Default::default() },
            )
            .count;
            assert_eq!(c, reference, "steal={steal} warps={warps} threads={threads}");
        }
    }
}

#[test]
fn dm_dfs_rides_the_same_scheduler() {
    use dumato::baselines::{App, DmDfs};
    let g = generators::erdos_renyi(40, 0.25, 17);
    let engine = Runner::run(
        &g,
        &CliqueCount::new(4),
        &EngineConfig { warps: 16, threads: 3, ..Default::default() },
    )
    .count;
    for steal in [false, true] {
        let mut d = DmDfs::new(App::Clique, 4);
        d.lanes = 128;
        d.threads = 3;
        d.steal = steal;
        let r = d.run(&g);
        assert_eq!(r.count, engine, "steal={steal}");
        assert_eq!(r.metrics.thread_spawns, 3);
    }
}
