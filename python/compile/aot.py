"""AOT lowering: jax model -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``.hlo.txt`` per (function, shape variant) plus ``manifest.txt``
(a simple ``name|file|inputs|outputs`` listing the rust runtime parses —
no JSON dependency needed on the rust side).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, function, example-input specs)
# Variants cover the graph sizes the benches feed: triangles over dense
# adjacency tiles, and intersect batches sized for the engine's warp count.
TRIANGLE_SIDES = (256, 512, 1024)
INTERSECT_VARIANTS = ((1024, 32), (1024, 128), (4096, 32))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s: jax.ShapeDtypeStruct) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def artifact_entries():
    """Yield (name, lowered, in_specs, n_outputs) for every artifact."""
    for n in TRIANGLE_SIDES:
        spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
        yield (
            f"triangle_{n}",
            jax.jit(model.triangle_count).lower(spec),
            [spec],
            1,
        )
        yield (
            f"motif3_{n}",
            jax.jit(model.motif3_census).lower(spec),
            [spec],
            2,
        )
    for b, w in INTERSECT_VARIANTS:
        spec = jax.ShapeDtypeStruct((b, w), jnp.int32)
        yield (
            f"intersect_{b}x{w}",
            jax.jit(model.intersect_count).lower(spec, spec),
            [spec, spec],
            2,
        )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, lowered, in_specs, n_out in artifact_entries():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        inputs = ";".join(spec_str(s) for s in in_specs)
        manifest_lines.append(f"{name}|{fname}|{inputs}|{n_out}")
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest.txt ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
