"""Build-time compile package: L2 jax model + L1 Pallas kernels + AOT lowering.

Never imported at runtime — ``make artifacts`` runs once and the rust binary
is self-contained afterwards.
"""
