"""Layer-1 Pallas kernels for DuMato's compute hot-spots.

All kernels are lowered with ``interpret=True`` so the resulting HLO runs on
any PJRT backend (including the rust CPU client). See DESIGN.md
§Hardware-Adaptation for the GPU-warp -> TPU-MXU mapping.
"""

from .triangle import triangle_kernel_call, TRIANGLE_BLOCK
from .intersect import intersect_count_call, INTERSECT_ROWS

__all__ = [
    "triangle_kernel_call",
    "TRIANGLE_BLOCK",
    "intersect_count_call",
    "INTERSECT_ROWS",
]
