"""Tiled masked-matmul Pallas kernel: C = (A @ A) * A per tile.

Triangle counting is ``sum(A^2 * A) / 6`` for an undirected 0/1 adjacency
matrix A.  The paper computes k=3 cliques with warp-SIMD adjacency-list
intersections; the TPU rethink (DESIGN.md §Hardware-Adaptation) turns the
intersection into a *blocked dense matmul* so the MXU systolic array does
128x128 multiply-accumulates per step instead of 32-lane compares.

BlockSpec schedule (the threadblock analogue):
  grid = (N/B, N/B, N/B); step (i, j, k) loads A[i,k] and A[k,j] into VMEM,
  accumulates into the output tile C[i,j] (revisited across k), and applies
  the adjacency mask on the last k step.  VMEM footprint = 4 tiles
  (a, b, mask, out) * B*B*4 bytes; B=128 -> 256 KiB, far below the ~16 MiB
  VMEM budget, leaving room for double-buffering by the pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge. 128 matches the MXU systolic array edge; the CPU
# interpret path accepts any divisor of N.
TRIANGLE_BLOCK = 128


def _triangle_kernel(a_ref, b_ref, m_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o += a @ b; mask on the final k step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _mask():
        o_ref[...] *= m_ref[...]


def triangle_kernel_call(adj: jax.Array, block: int = TRIANGLE_BLOCK) -> jax.Array:
    """Return the masked square ``(adj @ adj) * adj`` of a dense f32 adjacency.

    ``adj`` must be square with side divisible by ``block``. The caller
    (L2 model) reduces the result to the triangle count.
    """
    n = adj.shape[0]
    if adj.shape != (n, n):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if n % block != 0:
        raise ValueError(f"side {n} not divisible by block {block}")
    nb = n // block
    kernel = functools.partial(_triangle_kernel, nk=nb)
    return pl.pallas_call(
        kernel,
        grid=(nb, nb, nb),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),  # A row-tile
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),  # A col-tile
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),  # mask tile
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(adj, adj, adj)
