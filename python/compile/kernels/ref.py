"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""

import jax
import jax.numpy as jnp
from jax import lax


def triangle_ref(adj: jax.Array) -> jax.Array:
    """Masked square ``(A @ A) * A`` — oracle for triangle_kernel_call."""
    return jnp.dot(adj, adj, preferred_element_type=jnp.float32) * adj


def triangle_count_ref(adj: jax.Array) -> jax.Array:
    """Number of triangles in an undirected 0/1 adjacency matrix."""
    return jnp.sum(triangle_ref(adj)) / 6.0


def intersect_count_ref(cur: jax.Array, nbr: jax.Array):
    """AND + per-row popcount — oracle for intersect_count_call."""
    inter = cur & nbr
    counts = jnp.sum(lax.population_count(inter), axis=1).astype(jnp.int32)
    return inter, counts
