"""Batched bitmap intersect + popcount Pallas kernel.

The paper's clique-counting hot loop intersects the current candidate set
with the adjacency of the vertex being added (warp-SIMD compares).  Here
both sets are ``int32`` bitmaps (32 vertices per word); one kernel step ANDs
a ``[ROWS, W]`` tile and popcounts each row — the vectorized analogue of
``aggregate_counter`` over a compacted extensions array.

Outputs both the intersected bitmaps (the next level's candidate sets) and
the per-row counts (the last level's clique tally).

The interchange dtype is int32 (not uint32): the rust `xla` crate constructs
literals for the signed types; popcount is bit-pattern identical.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Rows processed per grid step. 32 keeps the tile comfortably in VMEM for
# word counts up to several hundred (32 * 512 * 4 B = 64 KiB per operand).
INTERSECT_ROWS = 32


def _intersect_kernel(a_ref, b_ref, o_ref, c_ref):
    inter = a_ref[...] & b_ref[...]
    o_ref[...] = inter
    c_ref[...] = jnp.sum(lax.population_count(inter), axis=1).astype(jnp.int32)


def intersect_count_call(cur: jax.Array, nbr: jax.Array, rows: int = INTERSECT_ROWS):
    """AND two ``[B, W] int32`` bitmap batches; return (bitmaps, counts).

    ``B`` must be divisible by ``rows``.
    """
    if cur.shape != nbr.shape or cur.ndim != 2:
        raise ValueError(f"shape mismatch: {cur.shape} vs {nbr.shape}")
    b, w = cur.shape
    if b % rows != 0:
        raise ValueError(f"batch {b} not divisible by row block {rows}")
    return pl.pallas_call(
        _intersect_kernel,
        grid=(b // rows,),
        in_specs=[
            pl.BlockSpec((rows, w), lambda i: (i, 0)),
            pl.BlockSpec((rows, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, w), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cur, nbr)
