"""Layer-2 jax model: DuMato's offloadable compute graphs.

These functions are what ``aot.py`` lowers to HLO text.  They call the
Layer-1 Pallas kernels so the kernels lower into the same HLO module, and
add the surrounding reduction/bookkeeping that the rust coordinator expects.
"""

import jax
import jax.numpy as jnp

from .kernels import triangle_kernel_call, intersect_count_call


def triangle_count(adj: jax.Array) -> tuple[jax.Array]:
    """Count triangles of a dense f32 0/1 adjacency matrix.

    Returns a 1-tuple (the AOT bridge lowers with return_tuple=True).
    The division by 6 removes the 3! orderings of each triangle.
    """
    masked = triangle_kernel_call(adj)
    return (jnp.sum(masked) / 6.0,)


def intersect_count(cur: jax.Array, nbr: jax.Array):
    """Batched candidate-set intersection for the clique hot loop.

    cur, nbr: [B, W] int32 bitmaps. Returns (intersections [B, W] int32,
    counts [B] int32).
    """
    inter, counts = intersect_count_call(cur, nbr)
    return (inter, counts)


def motif3_census(adj: jax.Array):
    """Closed-form 3-vertex motif census from the adjacency matrix.

    Returns (wedge_count, triangle_count): the two connected 3-motifs.
    Wedges (paths of length 2) = sum_v C(deg_v, 2) - 3 * triangles.
    Exercises kernel + jnp composition in a single lowered module.
    """
    masked = triangle_kernel_call(adj)
    triangles = jnp.sum(masked) / 6.0
    deg = jnp.sum(adj, axis=1)
    paths2 = jnp.sum(deg * (deg - 1.0) / 2.0)
    wedges = paths2 - 3.0 * triangles
    return (wedges, triangles)
