"""L1 triangle kernel vs pure-jnp oracle — the core correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.triangle import triangle_kernel_call
from compile.kernels.ref import triangle_ref, triangle_count_ref
from conftest import random_adjacency


@pytest.mark.parametrize("n,block", [(64, 32), (128, 32), (128, 64), (256, 128)])
def test_matches_ref(rng, n, block):
    adj = random_adjacency(rng, n, 0.1)
    out = triangle_kernel_call(jnp.asarray(adj), block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(triangle_ref(adj)))


def test_complete_graph_count(rng):
    """K_n has C(n,3) triangles."""
    n = 64
    adj = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    out = triangle_kernel_call(jnp.asarray(adj), block=32)
    count = float(np.sum(out) / 6.0)
    assert count == n * (n - 1) * (n - 2) / 6


def test_triangle_free_graph(rng):
    """A star graph has no triangles."""
    n = 64
    adj = np.zeros((n, n), np.float32)
    adj[0, 1:] = 1.0
    adj[1:, 0] = 1.0
    out = triangle_kernel_call(jnp.asarray(adj), block=32)
    assert float(np.sum(out)) == 0.0


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        triangle_kernel_call(jnp.zeros((8, 16), jnp.float32), block=8)
    with pytest.raises(ValueError):
        triangle_kernel_call(jnp.zeros((48, 48), jnp.float32), block=32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    p=st.floats(0.0, 0.5),
)
def test_property_matches_ref(seed, nb, block, p):
    """Sweep shapes/densities: kernel == oracle, count == brute force."""
    rng = np.random.default_rng(seed)
    n = nb * block
    adj = random_adjacency(rng, n, p)
    out = np.asarray(triangle_kernel_call(jnp.asarray(adj), block=block))
    np.testing.assert_allclose(out, np.asarray(triangle_ref(adj)))
    # brute-force triangle count on the small side
    if n <= 48:
        brute = 0
        idx = np.arange(n)
        for i in range(n):
            for j in range(i + 1, n):
                if adj[i, j]:
                    brute += int(np.sum(adj[i] * adj[j]))
        brute //= 3
        assert float(triangle_count_ref(adj)) == brute
        assert float(np.sum(out) / 6.0) == brute
