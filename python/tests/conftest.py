import os
import sys

# Allow `pytest python/tests` from the repo root as well as `cd python`.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xD0AA70)


def random_adjacency(rng, n: int, p: float) -> np.ndarray:
    """Symmetric 0/1 f32 adjacency with zero diagonal."""
    a = (rng.random((n, n)) < p).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.T
