"""L2 model functions: composition, shapes, and known closed forms."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from conftest import random_adjacency


def test_triangle_count_complete_graph():
    n = 128
    adj = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    (count,) = model.triangle_count(jnp.asarray(adj))
    assert float(count) == n * (n - 1) * (n - 2) / 6


def test_triangle_count_cycle():
    """An n-cycle (n>3) has no triangles."""
    n = 128
    adj = np.zeros((n, n), np.float32)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1.0
    (count,) = model.triangle_count(jnp.asarray(adj))
    assert float(count) == 0.0


def test_motif3_census_closed_forms(rng):
    n = 128
    adj = random_adjacency(rng, n, 0.08)
    wedges, triangles = model.motif3_census(jnp.asarray(adj))
    # brute-force over all 3-subsets is O(n^3); use matrix identities instead
    a2 = adj @ adj
    tri = float(np.sum(a2 * adj)) / 6.0
    deg = adj.sum(axis=1)
    wed = float(np.sum(deg * (deg - 1) / 2)) - 3.0 * tri
    assert float(triangles) == pytest.approx(tri)
    assert float(wedges) == pytest.approx(wed)


def test_motif3_census_triangle_graph():
    """A single triangle: 1 triangle, 0 wedges."""
    adj = np.zeros((128, 128), np.float32)
    for i, j in [(0, 1), (1, 2), (0, 2)]:
        adj[i, j] = adj[j, i] = 1.0
    wedges, triangles = model.motif3_census(jnp.asarray(adj))
    assert float(triangles) == 1.0
    assert float(wedges) == 0.0


def test_intersect_count_model(rng):
    b, w = 64, 8
    cur = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    nbr = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    inter, counts = model.intersect_count(jnp.asarray(cur), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(inter), cur & nbr)
    assert counts.shape == (b,)
