"""L1 intersect kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.intersect import intersect_count_call
from compile.kernels.ref import intersect_count_ref


def _popcount_rows(a: np.ndarray) -> np.ndarray:
    bits = np.unpackbits(a.view(np.uint8), axis=-1)
    return bits.reshape(a.shape[0], -1).sum(axis=1).astype(np.int32)


@pytest.mark.parametrize("b,w,rows", [(32, 4, 32), (64, 8, 32), (128, 32, 32), (64, 8, 16)])
def test_matches_ref(rng, b, w, rows):
    cur = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    nbr = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    inter, counts = intersect_count_call(jnp.asarray(cur), jnp.asarray(nbr), rows=rows)
    ref_inter, ref_counts = intersect_count_ref(cur, nbr)
    np.testing.assert_array_equal(np.asarray(inter), np.asarray(ref_inter))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref_counts))


def test_counts_against_numpy_popcount(rng):
    b, w = 64, 8
    cur = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    nbr = rng.integers(0, 2**31, (b, w), dtype=np.int32)
    _, counts = intersect_count_call(jnp.asarray(cur), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(counts), _popcount_rows(cur & nbr))


def test_disjoint_and_identical(rng):
    b, w = 32, 4
    a = np.full((b, w), 0x55555555, np.int32)
    z = np.full((b, w), ~np.int32(0x55555555), np.int32)
    _, c0 = intersect_count_call(jnp.asarray(a), jnp.asarray(z))
    assert np.all(np.asarray(c0) == 0)
    _, c1 = intersect_count_call(jnp.asarray(a), jnp.asarray(a))
    assert np.all(np.asarray(c1) == w * 16)


def test_negative_words_popcount_correct(rng):
    """Sign bit must count as a set bit (int32 interchange, u32 semantics)."""
    a = np.full((32, 4), -1, np.int32)  # all 32 bits set
    _, c = intersect_count_call(jnp.asarray(a), jnp.asarray(a))
    assert np.all(np.asarray(c) == 4 * 32)


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        intersect_count_call(jnp.zeros((8, 4), jnp.int32), jnp.zeros((8, 8), jnp.int32))
    with pytest.raises(ValueError):
        intersect_count_call(jnp.zeros((20, 4), jnp.int32), jnp.zeros((20, 4), jnp.int32), rows=32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    rows=st.sampled_from([8, 16, 32]),
    w=st.integers(1, 16),
)
def test_property_matches_ref(seed, blocks, rows, w):
    rng = np.random.default_rng(seed)
    b = blocks * rows
    cur = rng.integers(-(2**31), 2**31, (b, w)).astype(np.int32)
    nbr = rng.integers(-(2**31), 2**31, (b, w)).astype(np.int32)
    inter, counts = intersect_count_call(jnp.asarray(cur), jnp.asarray(nbr), rows=rows)
    np.testing.assert_array_equal(np.asarray(inter), cur & nbr)
    np.testing.assert_array_equal(np.asarray(counts), _popcount_rows(cur & nbr))
