"""AOT bridge: every artifact lowers to parseable HLO text with the right
entry signature, and the lowered modules still compute correct numbers when
executed through jax (the rust side re-checks execution via PJRT)."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_structure():
    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    lowered = jax.jit(model.triangle_count).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True => tuple-shaped root
    assert "(f32[])" in text or "tuple" in text


def test_artifact_entries_cover_variants():
    entries = list(aot.artifact_entries())
    names = [e[0] for e in entries]
    for n in aot.TRIANGLE_SIDES:
        assert f"triangle_{n}" in names
        assert f"motif3_{n}" in names
    for b, w in aot.INTERSECT_VARIANTS:
        assert f"intersect_{b}x{w}" in names


def test_spec_str_format():
    s = jax.ShapeDtypeStruct((4, 8), jnp.int32)
    assert aot.spec_str(s) == "int32[4,8]"


def test_manifest_written(tmp_path, monkeypatch):
    # Shrink the variant set so the test stays fast.
    monkeypatch.setattr(aot, "TRIANGLE_SIDES", (256,))
    monkeypatch.setattr(aot, "INTERSECT_VARIANTS", ((1024, 32),))
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(tmp_path)])
    aot.main()
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 3
    for line in manifest:
        name, fname, inputs, n_out = line.split("|")
        assert (tmp_path / fname).exists()
        assert int(n_out) >= 1
        text = (tmp_path / fname).read_text()
        assert text.startswith("HloModule")
