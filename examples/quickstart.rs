//! Quickstart: count cliques and motifs on a Table III stand-in dataset.
//!
//! ```
//! cargo run --release --example quickstart
//! ```

use dumato::apps::{CliqueCount, MotifCount};
use dumato::balance::LbConfig;
use dumato::canon::patterns::pattern_name;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, GraphStats};
use dumato::util::fmt_count;

fn main() {
    // 1. Get a graph: a deterministic stand-in for the paper's Citeseer.
    let g = generators::CITESEER.generate(1);
    println!("{}", GraphStats::table_header());
    println!("{}", GraphStats::of(&g).table_row());

    // 2. Configure the engine: 1024 virtual warps, load balancing at the
    //    paper's clique threshold (40%).
    let cfg = EngineConfig {
        warps: 1024,
        ..Default::default()
    }
    .with_lb(LbConfig::clique());

    // 3. Count 4-cliques.
    let r = Runner::run(&g, &CliqueCount::new(4), &cfg);
    println!(
        "\n4-cliques: {}   (sim {:.4}s, wall {:.3}s, {} LB migrations)",
        fmt_count(r.count),
        r.metrics.sim_seconds,
        r.metrics.wall_seconds,
        r.metrics.migrations
    );

    // 4. A 3-motif census with in-kernel canonical relabeling.
    let cfg = EngineConfig {
        warps: 1024,
        ..Default::default()
    }
    .with_lb(LbConfig::motif());
    let r = Runner::run(&g, &MotifCount::new(3), &cfg);
    println!("\n3-motif census:");
    for &(bm, c) in &r.patterns {
        println!("  {:<12} {}", pattern_name(3, bm), fmt_count(c));
    }
}
