//! Motif census across k on a skewed collaboration-network stand-in,
//! showing the load balancer's effect (the paper's headline motif story).
//!
//! ```
//! cargo run --release --example motif_census [-- --scale 0.1]
//! ```

use dumato::apps::MotifCount;
use dumato::balance::LbConfig;
use dumato::canon::patterns::pattern_name;
use dumato::cli::Args;
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::report::Table;
use dumato::util::fmt_count;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let scale: f64 = args.parse_or("scale", 0.05)?;
    let g = generators::ASTROPH.scaled(scale).generate(1);
    println!(
        "dataset={} |V|={} |E|={} max_deg={}\n",
        g.name(),
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    for k in 3..=4 {
        let base = EngineConfig {
            warps: 1024,
            ..Default::default()
        };
        let wc = Runner::run(&g, &MotifCount::new(k), &base);
        let opt = Runner::run(
            &g,
            &MotifCount::new(k),
            &base.clone().with_lb(LbConfig::motif()),
        );
        let mut t = Table::new(
            format!(
                "{k}-motif census (DM_WC {:.4}s vs DM_OPT {:.4}s simulated; {} migrations)",
                wc.metrics.sim_seconds, opt.metrics.sim_seconds, opt.metrics.migrations
            ),
            &["pattern", "count"],
        );
        let total: u64 = opt.patterns.iter().map(|&(_, c)| c).sum();
        for &(bm, c) in &opt.patterns {
            t.row(vec![pattern_name(k, bm), fmt_count(c)]);
        }
        t.row(vec!["TOTAL".into(), fmt_count(total)]);
        println!("{}", t.render());
        // LB must not change the answer
        assert_eq!(wc.patterns, opt.patterns, "LB changed results!");
    }
    Ok(())
}
