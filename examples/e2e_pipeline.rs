//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. L1/L2 artifacts (Pallas kernels lowered via jax to HLO text) are
//!    loaded by the rust PJRT runtime and executed on a real graph:
//!    - triangle counting as the tiled masked matmul (MXU path);
//!    - the 3-motif census (wedges + triangles closed form);
//!    - a *two-stage batched clique pipeline*: stage 1 intersects
//!      adjacency bitmaps per edge (triangles), stage 2 re-intersects the
//!      stage-1 survivors (4-cliques) — the rust hot path batching work
//!      into the AOT-compiled intersect kernel, python nowhere in sight.
//! 2. Every XLA number is checked against the DuMato engine exactly.
//! 3. The paper's three-variant comparison (DM_DFS / DM_WC / DM_OPT) runs
//!    on a skewed stand-in and prints the Table IV-style speedups.
//!
//! ```
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use anyhow::{ensure, Context, Result};

use dumato::apps::CliqueCount;
use dumato::balance::LbConfig;
use dumato::baselines::{App, DmDfs};
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::{generators, CsrGraph};
use dumato::report::Table;
use dumato::runtime::{artifacts_dir, XlaRuntime};
use dumato::util::{fmt_count, Timer};

/// Adjacency bitmaps over <= 1024 vertices as 32 i32 words per row.
struct Bitmaps {
    words: usize,
    rows: Vec<i32>,
}

impl Bitmaps {
    fn build(g: &CsrGraph, words: usize) -> Self {
        let n = g.num_vertices();
        assert!(n <= words * 32);
        let mut rows = vec![0i32; n * words];
        for (u, v) in g.edges() {
            for (a, b) in [(u as usize, v as usize), (v as usize, u as usize)] {
                rows[a * words + (b >> 5)] |= 1 << (b & 31);
            }
        }
        Self { words, rows }
    }

    fn row(&self, v: usize) -> &[i32] {
        &self.rows[v * self.words..(v + 1) * self.words]
    }

    /// Mask selecting vertex ids strictly greater than `v`.
    fn greater_mask(words: usize, v: usize) -> Vec<i32> {
        let mut m = vec![0i32; words];
        for w in 0..words {
            for b in 0..32 {
                if w * 32 + b > v {
                    m[w] |= 1 << b;
                }
            }
        }
        m
    }
}

/// Two-stage batched clique pipeline through the AOT intersect kernel.
/// Stage 1: per edge (u,v), |N(u) ∩ N(v) ∩ {>v}| -> triangle count, and
/// the intersection bitmaps seed stage 2.
/// Stage 2: per (edge, w) survivor, |stage1 ∩ N(w) ∩ {>w}| -> 4-cliques.
fn clique_pipeline(rt: &mut XlaRuntime, g: &CsrGraph) -> Result<(u64, u64, usize)> {
    const B: usize = 1024; // batch rows per kernel launch
    let words = 32;
    let bm = Bitmaps::build(g, words);
    let masks: Vec<Vec<i32>> = (0..g.num_vertices())
        .map(|v| Bitmaps::greater_mask(words, v))
        .collect();

    let mut batches = 0usize;
    let mut triangles = 0u64;
    let mut cliques4 = 0u64;
    // stage-2 pending rows: (intersection-bitmap, w) expanded from stage 1
    let mut stage2_cur: Vec<i32> = Vec::new();
    let mut stage2_nbr: Vec<i32> = Vec::new();

    let flush_stage2 = |cur: &mut Vec<i32>, nbr: &mut Vec<i32>, cliques4: &mut u64, batches: &mut usize, rt: &mut XlaRuntime| -> Result<()> {
        while !cur.is_empty() {
            let rows = (cur.len() / words).min(B);
            let take = rows * words;
            let c: Vec<i32> = cur.drain(..take).collect();
            let n: Vec<i32> = nbr.drain(..take).collect();
            let (_, counts) = rt.intersect_count(rows, words, &c, &n)?;
            *cliques4 += counts.iter().map(|&x| x as u64).sum::<u64>();
            *batches += 1;
            if cur.len() < B * words {
                break; // keep a partial batch buffered until the end
            }
        }
        Ok(())
    };

    // stage 1 over all edges, in batches of B rows
    let edges: Vec<(u32, u32)> = g.edges().collect();
    for chunk in edges.chunks(B) {
        let rows = chunk.len();
        let mut cur = Vec::with_capacity(rows * words);
        let mut nbr = Vec::with_capacity(rows * words);
        for &(u, v) in chunk {
            // N(u) masked to ids > v; intersected with N(v) by the kernel
            for w in 0..words {
                cur.push(bm.row(u as usize)[w] & masks[v as usize][w]);
            }
            nbr.extend_from_slice(bm.row(v as usize));
        }
        let (inter, counts) = rt.intersect_count(rows, words, &cur, &nbr)?;
        batches += 1;
        triangles += counts.iter().map(|&x| x as u64).sum::<u64>();
        // expand stage-1 intersections into stage-2 rows
        for (r, &(_u, _v)) in chunk.iter().enumerate() {
            let row = &inter[r * words..(r + 1) * words];
            for wq in 0..words {
                let mut bits = row[wq] as u32;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let w = wq * 32 + b;
                    for q in 0..words {
                        stage2_cur.push(row[q] & masks[w][q]);
                    }
                    stage2_nbr.extend_from_slice(bm.row(w));
                }
            }
        }
        if stage2_cur.len() >= B * words {
            flush_stage2(&mut stage2_cur, &mut stage2_nbr, &mut cliques4, &mut batches, rt)?;
        }
    }
    // drain remaining stage-2 rows
    while !stage2_cur.is_empty() {
        let rows = stage2_cur.len() / words;
        let c: Vec<i32> = stage2_cur.drain(..).collect();
        let n: Vec<i32> = stage2_nbr.drain(..).collect();
        let (_, counts) = rt.intersect_count(rows, words, &c, &n)?;
        cliques4 += counts.iter().map(|&x| x as u64).sum::<u64>();
        batches += 1;
    }
    Ok((triangles, cliques4, batches))
}

fn main() -> Result<()> {
    let dir = artifacts_dir();
    ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let mut rt = XlaRuntime::new(&dir).context("PJRT runtime")?;
    println!("PJRT CPU runtime up; artifacts from {}\n", dir.display());

    // ---- workload: a clustered power-law graph that fits the 1024-wide
    // kernel variants ----
    let g = generators::PowerLawSpec {
        name: "e2e-powerlaw",
        vertices: 1000,
        edges: 5000,
        max_degree: 120,
        gamma: 2.2,
        closure: 0.25,
    }
    .generate(7);
    println!(
        "workload: {} |V|={} |E|={} max_deg={}",
        g.name(),
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    let cfg = EngineConfig {
        warps: 1024,
        ..Default::default()
    };
    let mut summary = Table::new(
        "L1/L2 artifacts through PJRT vs DuMato engine",
        &["quantity", "xla", "engine", "status"],
    );

    // 1) triangle counting via the tiled masked-matmul kernel
    let t = Timer::start();
    let xla_tri = rt.triangle_count(&g)?;
    let xla_tri_s = t.secs();
    let eng_tri = Runner::run(&g, &CliqueCount::new(3), &cfg).count;
    ensure!(xla_tri == eng_tri, "triangle mismatch: {xla_tri} vs {eng_tri}");
    summary.row(vec![
        "triangles (matmul kernel)".into(),
        fmt_count(xla_tri),
        fmt_count(eng_tri),
        format!("ok ({xla_tri_s:.3}s)"),
    ]);

    // 2) 3-motif census closed form
    let (wedges, tri2) = rt.motif3_census(&g)?;
    ensure!(tri2 == eng_tri);
    summary.row(vec![
        "3-motif census (wedges)".into(),
        fmt_count(wedges),
        "-".into(),
        "ok".into(),
    ]);

    // 3) the two-stage batched clique pipeline through the intersect kernel
    let t = Timer::start();
    let (p_tri, p_c4, batches) = clique_pipeline(&mut rt, &g)?;
    let pipe_s = t.secs();
    let eng_c4 = Runner::run(&g, &CliqueCount::new(4), &cfg).count;
    ensure!(p_tri == eng_tri, "pipeline stage-1 mismatch");
    ensure!(p_c4 == eng_c4, "pipeline stage-2 mismatch: {p_c4} vs {eng_c4}");
    summary.row(vec![
        "triangles (intersect pipeline)".into(),
        fmt_count(p_tri),
        fmt_count(eng_tri),
        "ok".into(),
    ]);
    summary.row(vec![
        "4-cliques (intersect pipeline)".into(),
        fmt_count(p_c4),
        fmt_count(eng_c4),
        format!("ok ({batches} kernel launches, {pipe_s:.3}s)"),
    ]);
    println!("{}", summary.render());

    // ---- the paper's three-variant comparison on a skewed stand-in ----
    let g = generators::ASTROPH.scaled(0.08).generate(1);
    println!(
        "variant comparison on {} |V|={} |E|={} (clique k=5):",
        g.name(),
        g.num_vertices(),
        g.num_edges()
    );
    let k = 5;
    let mut dfs = DmDfs::new(App::Clique, k);
    dfs.lanes = 1024 * 32;
    let r_dfs = dfs.run(&g);
    let r_wc = Runner::run(&g, &CliqueCount::new(k), &cfg);
    let r_opt = Runner::run(
        &g,
        &CliqueCount::new(k),
        &cfg.clone().with_lb(LbConfig::clique()),
    );
    ensure!(r_dfs.count == r_wc.count && r_wc.count == r_opt.count);
    let mut t = Table::new(
        "Table IV shape (simulated GPU seconds)",
        &["variant", "sim_time", "speedup", "count"],
    );
    let base = r_dfs.metrics.sim_seconds;
    for (name, m) in [
        ("DM_DFS", &r_dfs.metrics),
        ("DM_WC", &r_wc.metrics),
        ("DM_OPT", &r_opt.metrics),
    ] {
        t.row(vec![
            name.into(),
            format!("{:.4}", m.sim_seconds),
            format!("{:.1}x", base / m.sim_seconds),
            fmt_count(r_wc.count),
        ]);
    }
    println!("{}", t.render());
    println!("e2e pipeline OK — all layers compose, all counts agree.");
    Ok(())
}
