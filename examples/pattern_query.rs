//! Subgraph querying with `aggregate_store` [A3]: list the vertex sets of
//! every diamond (K4 minus an edge) in a DBLP-scale stand-in, and mine
//! 0.8-quasi-cliques — the two "custom semantics" uses of the API that the
//! paper motivates (§IV-E).
//!
//! ```
//! cargo run --release --example pattern_query
//! ```

use dumato::apps::{QuasiCliqueCount, SubgraphQuery};
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::util::fmt_count;

fn main() {
    let g = generators::DBLP.scaled(0.01).generate(1);
    println!(
        "dataset={} |V|={} |E|={}\n",
        g.name(),
        g.num_vertices(),
        g.num_edges()
    );
    let cfg = EngineConfig {
        warps: 512,
        ..Default::default()
    };

    // Diamond query: K4 minus one edge.
    let q = SubgraphQuery::new(4, &[(0, 1), (1, 2), (0, 2), (0, 3), (2, 3)]);
    let r = Runner::run(&g, &q, &cfg);
    let matches = q.matches(&r);
    println!(
        "diamonds: {} (of {} stored 4-subgraphs)",
        fmt_count(matches.len() as u64),
        fmt_count(r.stored.len() as u64)
    );
    for m in matches.iter().take(5) {
        println!("  {m:?}");
    }

    // Quasi-cliques: 4-vertex subgraphs with >= 80% of possible edges
    // (i.e. >= 5 of 6 edges: diamonds and 4-cliques).
    let qc = Runner::run(&g, &QuasiCliqueCount::new(4, 0.8), &cfg);
    println!("\n0.8-quasi-cliques (k=4): {}", fmt_count(qc.count));

    // cross-check: quasi-cliques(0.8) = diamonds + 4-cliques
    let cliques = Runner::run(&g, &dumato::apps::CliqueCount::new(4), &cfg);
    assert_eq!(
        qc.count,
        matches.len() as u64 + cliques.count,
        "quasi-clique census must equal diamonds + 4-cliques"
    );
    println!(
        "  = diamonds {} + 4-cliques {}  [ok]",
        fmt_count(matches.len() as u64),
        fmt_count(cliques.count)
    );
}
