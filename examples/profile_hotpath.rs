//! §Perf driver: times the engine hot path on a fixed workload so
//! optimization iterations are comparable (EXPERIMENTS.md §Perf).
use dumato::apps::{CliqueCount, MotifCount};
use dumato::engine::{EngineConfig, Runner};
use dumato::graph::generators;
use dumato::util::Timer;

fn main() {
    let g = generators::MICO.scaled(0.05).generate(1);
    println!("mico@0.05 |V|={} |E|={} maxdeg={}", g.num_vertices(), g.num_edges(), g.max_degree());
    let cfg = EngineConfig { warps: 1024, threads: 1, ..Default::default() };
    let t = Timer::start();
    let r = Runner::run(&g, &CliqueCount::new(5), &cfg);
    println!("clique k=5: count={} wall={:.3}s insts={}", r.count, t.secs(), r.metrics.total_insts);
    let t = Timer::start();
    let r = Runner::run(&g, &MotifCount::new(4), &cfg);
    let total: u64 = r.patterns.iter().map(|&(_,c)| c).sum();
    println!("motif  k=4: total={} wall={:.3}s insts={}", total, t.secs(), r.metrics.total_insts);
}
